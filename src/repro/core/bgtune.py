"""BackgroundTune: always-on dynamic tuning under live traffic.

ROADMAP item 2, built for the serving path's latency contract: a resolution
miss must never pay a tuning search inline. The :class:`BackgroundTune`
policy answers a miss with the heuristic config *immediately* (tier
``"bgtune"``, uncached) while handing the bucket to a
:class:`BackgroundTuner` — a bounded-queue worker thread that runs the full
autotune loop off the request path and hot-swaps the winning record into
the live database. Because bgtune resolutions are never cached, every
subsequent resolve re-consults :class:`~.runtime.ExactHit` first, so the
moment the record lands the bucket flips to the tuned config with zero
coordination — the fleet converges to 100% ExactHit with no request-path
stalls (Petrovič et al. 2019: dynamic autotuning pays off only when a slow
or failed candidate cannot stall the application's critical path).

Failure is steady-state here, same contract as the dispatch guard:

* the queue is bounded — an overloaded tuner *sheds* jobs (counted, and the
  shed bucket is re-offered by a later resolve) rather than growing without
  limit;
* the worker retries each job with backoff, and a job that exhausts its
  attempts is parked (warn_once + counter) so it cannot spin the worker;
* a worker *crash* (anything escaping the per-job ``except Exception``,
  e.g. the harness's ``InjectedWorkerCrash``) kills the worker loop only:
  the policy notices the dead worker and demotes itself — resolution falls
  through to plain Heuristic, and resolve never blocks on the tuner.

Promotion lands on the *request's* database key: the worker re-materializes
arguments at the key's (already bucketed, already shard-localized) shapes,
runs ``autotune(save=False)``, and ``db.put``s a record under exactly
``req.key`` — so an ExactHit follows on the very next resolve. With
``export_path`` set, every promotion also rewrites a standalone delta
database (promoted records only) via the same atomic write-to-temp path, a
fleet's mechanism for shipping freshly-learned records to its peers.

Obs: ``bgtune.queue_depth`` gauge, ``bgtune.promotions`` counter,
``bgtune.promote_latency_s`` histogram (enqueue → record live), plus
``bgtune.shed`` / ``bgtune.failures`` counters. The worker thread starts
with a fresh contextvar context, so the collector active at *offer* time is
captured with the job and re-entered around its execution.
"""
from __future__ import annotations

import contextlib
import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Optional, Tuple

from ..obs.collect import ObsCollector, current_collector as _obs_collector
from ..testing.faults import fault_point as _fault_point
from .database import Record, TuningDatabase, now, split_key
from .runtime import (
    CoverSet,
    ExactHit,
    Heuristic,
    Reference,
    Resolution,
    ResolutionPolicy,
    ResolutionRequest,
    TunedRuntime,
    _as_tunable,
)


@dataclasses.dataclass
class _BgJob:
    """One queued tuning task, self-contained for the worker thread."""

    kernel: str
    key: str
    key_extra: str
    arg_shapes: Tuple[Tuple[int, ...], ...]
    arg_dtypes: Tuple[str, ...]
    db: TuningDatabase
    collector: ObsCollector
    enqueued: float                    # monotonic stamp (promote latency)


class BackgroundTuner:
    """Bounded async tuner: a worker thread promoting records off-path.

    ``budget`` is the per-job search budget (coordinate descent by default;
    ``search_factory(job) -> SearchAlgorithm`` overrides per job).
    ``device`` pins tuning measurements to a spare accelerator
    (``jax.default_device``) so search traffic never contends with serving.
    ``max_attempts``/``backoff_s`` bound per-job retries; ``max_queue``
    bounds memory. ``export_path`` keeps a standalone delta database of
    promoted records current on disk.

    Lifecycle: the worker starts lazily on the first :meth:`offer`;
    :meth:`drain` waits for the queue to empty (tests/shutdown);
    :meth:`stop` ends the worker. ``accepting`` is False once the worker
    has died or been stopped — the :class:`BackgroundTune` policy checks it
    and demotes itself rather than queueing into the void.
    """

    def __init__(
        self,
        budget: int = 16,
        evaluator: Optional[Any] = None,
        search_factory: Optional[Callable[[_BgJob], Any]] = None,
        max_queue: int = 64,
        max_attempts: int = 3,
        backoff_s: float = 0.05,
        export_path: Optional[str] = None,
        device: Optional[Any] = None,
        arg_seed: int = 0,
        name: str = "bgtune",
    ):
        self.budget = int(budget)
        self.evaluator = evaluator
        self.search_factory = search_factory
        self.max_attempts = max(1, int(max_attempts))
        self.backoff_s = float(backoff_s)
        self.export_path = export_path
        self.device = device
        self.arg_seed = int(arg_seed)
        self.name = name
        self._q: "queue.Queue[_BgJob]" = queue.Queue(maxsize=max(1, int(max_queue)))
        self._lock = threading.Lock()
        self._seen: set = set()        # keys queued, running, or finished
        self._inflight = 0             # queued + currently-running jobs
        self._thread: Optional[threading.Thread] = None
        self._stopped = threading.Event()
        self._death: Optional[str] = None
        self._promoted: list = []      # Records, in promotion order
        self.promotions = 0
        self.failures = 0
        self.shed = 0

    # -- lifecycle -----------------------------------------------------------
    @property
    def accepting(self) -> bool:
        """Whether offers will (eventually) be worked: not stopped, worker
        not dead. True before the lazy first start."""
        if self._stopped.is_set() or self._death is not None:
            return False
        t = self._thread
        return t is None or t.is_alive()

    def _ensure_started(self) -> None:
        if self._thread is not None:
            return
        with self._lock:
            if self._thread is None:
                self._thread = threading.Thread(
                    target=self._run, name=f"repro-{self.name}", daemon=True
                )
                self._thread.start()

    def stop(self, timeout: float = 5.0) -> None:
        self._stopped.set()
        t = self._thread
        if t is not None and t.is_alive():
            t.join(timeout)

    def drain(self, timeout: float = 30.0) -> bool:
        """Block until all offered jobs have finished (or the worker died).
        Returns True when the queue fully drained within `timeout`."""
        deadline = time.monotonic() + timeout
        while time.monotonic() < deadline:
            with self._lock:
                idle = self._inflight == 0
            if idle:
                return True
            if not self.accepting:
                return False
            time.sleep(0.005)
        return False

    # -- intake ---------------------------------------------------------------
    def offer(self, req: ResolutionRequest) -> bool:
        """Enqueue one bucket for background tuning (idempotent per key).

        Never blocks: a full queue sheds the offer (the key is released so
        a later resolve re-offers it). Returns False only when the tuner is
        no longer accepting at all.
        """
        if not self.accepting:
            return False
        key = req.key
        with self._lock:
            if key in self._seen:
                return True
            self._seen.add(key)
        col = _obs_collector()
        job = _BgJob(
            kernel=req.tunable.name,
            key=key,
            key_extra=req.key_extra,
            # The key's shapes are already bucketed (and, under a sharded
            # mesh, localized to the per-device shard) — materializing at
            # exactly these shapes re-derives exactly this key, so the
            # promoted record is an ExactHit for the live traffic.
            arg_shapes=split_key(key)[2],
            arg_dtypes=tuple(
                str(a.dtype) for a in req.args if hasattr(a, "dtype")
            ),
            db=req.db,
            collector=col,
            enqueued=time.monotonic(),
        )
        try:
            self._q.put_nowait(job)
        except queue.Full:
            with self._lock:
                self._seen.discard(key)
                self.shed += 1
            if col.enabled:
                col.counter("bgtune.shed", kernel=job.kernel)
            return True
        with self._lock:
            self._inflight += 1
        if col.enabled:
            col.gauge("bgtune.queue_depth", float(self._q.qsize()))
        self._ensure_started()
        return True

    # -- worker ---------------------------------------------------------------
    def _run(self) -> None:
        while not self._stopped.is_set():
            try:
                job = self._q.get(timeout=0.05)
            except queue.Empty:
                continue
            try:
                self._run_job(job)
            except BaseException as e:  # noqa: BLE001 — crash isolation
                # Anything that escaped the per-job retry loop (an injected
                # InjectedWorkerCrash, KeyboardInterrupt delivered here, a
                # MemoryError) kills THIS worker only. Record the cause so
                # `accepting` flips and the policy demotes to Heuristic —
                # the resolve path never notices beyond a tier change.
                self._death = f"{type(e).__name__}: {e}"
                job.collector.warn_once(
                    "bgtune.worker_dead", key=self.name,
                    kernel=job.kernel, error=self._death,
                )
                return
            finally:
                with self._lock:
                    self._inflight -= 1

    def _run_job(self, job: _BgJob) -> None:
        col = job.collector
        last: Optional[Exception] = None
        for attempt in range(1, self.max_attempts + 1):
            try:
                _fault_point(f"bgtune.worker:{job.kernel}", attempt=attempt)
                self._tune_one(job)
            except Exception as e:
                last = e
                time.sleep(self.backoff_s * attempt)
                continue
            latency = time.monotonic() - job.enqueued
            with self._lock:
                self.promotions += 1
            if col.enabled:
                col.counter("bgtune.promotions", kernel=job.kernel)
                col.observe(
                    "bgtune.promote_latency_s", latency, kernel=job.kernel
                )
                col.gauge("bgtune.queue_depth", float(self._q.qsize()))
            self._export_delta()
            return
        # Attempts exhausted: park the key (it stays in _seen, so resolve
        # keeps serving the heuristic for this bucket without re-queueing a
        # job that cannot succeed).
        with self._lock:
            self.failures += 1
        if col.enabled:
            col.counter("bgtune.failures", kernel=job.kernel)
        col.warn_once(
            "bgtune.job_failed", key=job.key, kernel=job.kernel,
            attempts=self.max_attempts,
            error=f"{type(last).__name__}: {last}" if last else "unknown",
        )

    def _tune_one(self, job: _BgJob) -> None:
        # Upward imports are lazy: the campaign layer imports core freely.
        from ..campaign.planner import TuningJob
        from ..campaign.runner import materialize_args
        from .search import CoordinateDescent
        from .tuner import autotune

        tunable = _as_tunable(job.kernel)
        args = materialize_args(
            TuningJob(
                kernel=job.kernel,
                arg_shapes=job.arg_shapes,
                arg_dtypes=job.arg_dtypes,
                key_extra=job.key_extra,
            ),
            seed=self.arg_seed,
        )
        search = (
            self.search_factory(job) if self.search_factory
            else CoordinateDescent(budget=self.budget)
        )
        dev = contextlib.nullcontext()
        if self.device is not None:
            import jax

            dev = jax.default_device(self.device)
        # Scoped runtime, same discipline as the campaign runner: nested
        # dispatches inside variant/reference evaluation resolve against the
        # job's db without touching the process default (the worker thread's
        # context starts at the root runtime, never the serving scope).
        with dev, TunedRuntime(db=job.db, name=f"{self.name}-worker"):
            res = autotune(
                tunable, args, search=search, evaluator=self.evaluator,
                db=job.db, key_extra=job.key_extra, save=False,
            )
        # Promote under the REQUEST's key, not a freshly-derived one: the
        # two agree by construction (bucketing is idempotent), but the
        # request key is the contract ExactHit will be consulted with.
        rec = Record(
            key=job.key,
            config=dict(res.best_config),
            objective=res.best_objective,
            evaluator=type(self.evaluator).__name__.replace(
                "Evaluator", ""
            ).lower() if self.evaluator is not None else "wallclock",
            evaluations=res.evaluations,
            timestamp=now(),
            meta={
                "source": "bgtune",
                "default_objective": res.default_objective,
            },
        )
        # db.put is lock-guarded and (for file-backed dbs) atomic on disk —
        # this is the hot swap: the next resolve's ExactHit sees it.
        job.db.put(rec)
        with self._lock:
            self._promoted.append(rec)

    def _export_delta(self) -> None:
        """Rewrite the standalone delta database of promoted records."""
        if not self.export_path:
            return
        with self._lock:
            recs = list(self._promoted)
        delta = TuningDatabase(None)
        for r in recs:
            delta.put(r, save=False)
        delta.path = self.export_path
        delta.save()

    def snapshot(self) -> dict:
        with self._lock:
            return {
                "accepting": self.accepting,
                "queue_depth": self._q.qsize(),
                "inflight": self._inflight,
                "promotions": self.promotions,
                "failures": self.failures,
                "shed": self.shed,
                "death": self._death,
            }

    def __repr__(self) -> str:
        return (
            f"<BackgroundTuner {self.name} accepting={self.accepting} "
            f"promotions={self.promotions} failures={self.failures}>"
        )


class BackgroundTune(ResolutionPolicy):
    """Resolution tier: serve the heuristic now, tune in the background.

    Sits between ExactHit and CoverSet in :func:`background_policy` — ahead
    of CoverSet deliberately: a cover hit caches and would end the story at
    a transferred config, whereas this tier keeps the bucket uncached until
    the background job promotes a measured *exact* record. Returns ``None``
    (demoting to whatever follows) once the tuner stops accepting — a dead
    worker turns the pipeline into plain heuristic serving, never an error.
    """

    name = "bgtune"

    def __init__(self, tuner: BackgroundTuner):
        self.tuner = tuner

    def resolve(self, req: ResolutionRequest) -> Optional[Resolution]:
        if not self.tuner.offer(req):
            return None
        # cache=False is the hot-swap hook: every resolve of this bucket
        # re-runs the pipeline, so ExactHit wins the moment the promoted
        # record lands in the db.
        return Resolution(
            req.tunable.default_config(*req.args), self.name, cache=False
        )


def background_policy(tuner: BackgroundTuner) -> Tuple[ResolutionPolicy, ...]:
    """The serving pipeline for always-on dynamic tuning.

    ``(ExactHit, BackgroundTune, CoverSet, Heuristic, Reference)`` — no
    TuneNow: the whole point is that nothing tunes on the request path.
    CoverSet/Heuristic still terminate the chain when the tuner demotes.
    """
    return (ExactHit(), BackgroundTune(tuner), CoverSet(), Heuristic(), Reference())
