"""Hardware profiles and platform keys.

Performance portability (the paper's C4) requires tuning results to be keyed
by *platform*: the same generic code specializes differently per machine.
A :class:`HardwareProfile` carries the peaks the analytic evaluator needs
(roofline terms) plus the capacity constraints (VMEM) that prune kernel tile
spaces.

Constants for TPU v5e follow the brief: 197 TFLOP/s bf16 per chip,
819 GB/s HBM, ~50 GB/s/link ICI, 16 GiB HBM, 128 MiB VMEM.
"""
from __future__ import annotations

import dataclasses

import jax


@dataclasses.dataclass(frozen=True)
class HardwareProfile:
    name: str                      # platform key for the tuning database
    peak_flops_bf16: float         # FLOP/s per chip
    hbm_bandwidth: float           # bytes/s per chip
    ici_bandwidth: float           # bytes/s per link
    hbm_bytes: int                 # per-chip HBM capacity
    vmem_bytes: int                # per-core VMEM (tile working-set budget)
    mxu_dim: int = 128             # systolic array native tile edge
    lanes: int = 128               # VPU lane count (last-dim alignment)
    sublanes: int = 8              # second-to-last-dim alignment (fp32)


TPU_V5E = HardwareProfile(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    hbm_bandwidth=819e9,
    ici_bandwidth=50e9,
    hbm_bytes=16 * 1024**3,
    vmem_bytes=128 * 1024**2,
)

TPU_V4 = HardwareProfile(
    name="tpu-v4",
    peak_flops_bf16=275e12,
    hbm_bandwidth=1228e9,
    ici_bandwidth=100e9,
    hbm_bytes=32 * 1024**3,
    vmem_bytes=128 * 1024**2,
)

# The host CPU is a legitimate tuning platform (the paper's own Figure 1 is a
# CPU result): wall-clock evaluation happens here. Peaks are rough single-core
# numbers; they only matter for cost-model scoring, which on CPU we do not use.
CPU_HOST = HardwareProfile(
    name="cpu-host",
    peak_flops_bf16=100e9,
    hbm_bandwidth=20e9,
    ici_bandwidth=10e9,
    hbm_bytes=32 * 1024**3,
    vmem_bytes=32 * 1024**2,   # ~L2/L3 budget analogue for tile pruning
)

PROFILES = {p.name: p for p in (TPU_V5E, TPU_V4, CPU_HOST)}


def detect_platform() -> HardwareProfile:
    """Key for *this* process's backend.

    On a real v5e pod ``jax.devices()[0].platform == 'tpu'``; in this
    container it is 'cpu'. Tuning records are stored under the detected key,
    so a database produced here never shadows a TPU database — that isolation
    is what makes shipping per-platform DBs safe.
    """
    plat = jax.devices()[0].platform
    if plat == "tpu":
        kind = getattr(jax.devices()[0], "device_kind", "").lower()
        return TPU_V4 if "v4" in kind else TPU_V5E
    return CPU_HOST
