"""Hardware profiles, platform fingerprinting, and the override escape hatch.

Performance portability (the paper's C4) requires tuning results to be keyed
by *platform*: the same generic code specializes differently per machine.
A :class:`HardwareProfile` carries the peaks the analytic evaluator needs
(roofline terms) plus the capacity constraints (VMEM) that prune kernel tile
spaces.

:func:`detect_platform` fingerprints ``jax.devices()`` into one of the known
profiles (tpu-v4 / tpu-v5e / cpu-host), so the dispatch runtime and the
campaign tools namespace their databases automatically — no caller wires a
platform string. When automatic detection is wrong or too coarse (a new TPU
generation, an A/B experiment that must not share records with production),
the escape hatch overrides it, in precedence order:

1. an explicit ``detect_platform(override=...)`` argument;
2. :func:`set_platform_override` (process-wide, e.g. from a launcher flag);
3. the ``REPRO_PLATFORM`` environment variable.

An override naming a known profile selects it; an unknown name clones the
fingerprinted profile under the new name, so roofline peaks stay sensible
while the database namespace is fully isolated.

Constants for TPU v5e follow the brief: 197 TFLOP/s bf16 per chip,
819 GB/s HBM, ~50 GB/s/link ICI, 16 GiB HBM, 128 MiB VMEM.
"""
from __future__ import annotations

import dataclasses
import os
from typing import Optional, Union

import jax


@dataclasses.dataclass(frozen=True)
class HardwareProfile:
    name: str                      # platform key for the tuning database
    peak_flops_bf16: float         # FLOP/s per chip
    hbm_bandwidth: float           # bytes/s per chip
    ici_bandwidth: float           # bytes/s per link
    hbm_bytes: int                 # per-chip HBM capacity
    vmem_bytes: int                # per-core VMEM (tile working-set budget)
    mxu_dim: int = 128             # systolic array native tile edge
    lanes: int = 128               # VPU lane count (last-dim alignment)
    sublanes: int = 8              # second-to-last-dim alignment (fp32)


TPU_V5E = HardwareProfile(
    name="tpu-v5e",
    peak_flops_bf16=197e12,
    hbm_bandwidth=819e9,
    ici_bandwidth=50e9,
    hbm_bytes=16 * 1024**3,
    vmem_bytes=128 * 1024**2,
)

TPU_V4 = HardwareProfile(
    name="tpu-v4",
    peak_flops_bf16=275e12,
    hbm_bandwidth=1228e9,
    ici_bandwidth=100e9,
    hbm_bytes=32 * 1024**3,
    vmem_bytes=128 * 1024**2,
)

# The host CPU is a legitimate tuning platform (the paper's own Figure 1 is a
# CPU result): wall-clock evaluation happens here. Peaks are rough single-core
# numbers; they only matter for cost-model scoring, which on CPU we do not use.
CPU_HOST = HardwareProfile(
    name="cpu-host",
    peak_flops_bf16=100e9,
    hbm_bandwidth=20e9,
    ici_bandwidth=10e9,
    hbm_bytes=32 * 1024**3,
    vmem_bytes=32 * 1024**2,   # ~L2/L3 budget analogue for tile pruning
)

PROFILES = {p.name: p for p in (TPU_V5E, TPU_V4, CPU_HOST)}

# Process-wide explicit override (set_platform_override / REPRO_PLATFORM).
_override: Optional[str] = None


def set_platform_override(name: Union[str, HardwareProfile, None]) -> None:
    """Pin the platform key for this process (None restores auto-detection).

    This is the escape hatch for hosts where fingerprinting is wrong or too
    coarse: launchers expose it as ``--platform``. It takes effect for every
    subsequent :func:`detect_platform` call and for runtimes constructed
    without an explicit ``platform=``.
    """
    global _override
    _override = name.name if isinstance(name, HardwareProfile) else name


def platform_override() -> Optional[str]:
    """The active override name (explicit call wins over $REPRO_PLATFORM)."""
    return _override or os.environ.get("REPRO_PLATFORM") or None


def _fingerprint() -> HardwareProfile:
    """Map ``jax.devices()`` onto a known profile.

    On a real pod ``jax.devices()[0].platform == 'tpu'`` and ``device_kind``
    distinguishes generations (e.g. "TPU v4", "TPU v5 lite"); in this
    container it is 'cpu'. Tuning records are stored under the detected key,
    so a database produced here never shadows a TPU database — that
    isolation is what makes shipping per-platform DBs safe.
    """
    dev = jax.devices()[0]
    if dev.platform == "tpu":
        kind = getattr(dev, "device_kind", "").lower()
        return TPU_V4 if "v4" in kind else TPU_V5E
    return CPU_HOST


def detect_platform(override: Optional[str] = None) -> HardwareProfile:
    """The :class:`HardwareProfile` this process tunes and dispatches under.

    ``override`` (or the process override, see :func:`set_platform_override`)
    short-circuits fingerprinting: a known profile name selects it; an
    unknown name clones the fingerprinted profile under that name — the
    database namespace is isolated while roofline peaks stay sensible.
    """
    name = override or platform_override()
    if name:
        prof = PROFILES.get(name)
        if prof is None:
            prof = dataclasses.replace(_fingerprint(), name=name)
        return prof
    return _fingerprint()
