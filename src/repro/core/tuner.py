"""Tuner orchestration: space × search × evaluator → best correct variant.

This is the paper's §2 loop end-to-end:

  1. the reference implementation runs once to produce reference outputs;
  2. the search strategy proposes configs;
  3. each config is bound to a variant, compiled, executed and measured;
  4. outputs are compared with the reference (gate), failures pruned;
  5. the best surviving variant is recorded in the per-platform database.

`tune_or_lookup` is the legacy deployment helper (the dispatch runtime's
policy pipeline supersedes it): database hit ⇒ zero-cost specialization
(performance portability); miss ⇒ either tune now (`allow_tune=True`) or
fall back to the shape heuristic.
"""
from __future__ import annotations

import dataclasses
import functools
import logging
import time
from typing import Any, Callable, Dict, Optional, Sequence

import jax

from .annotate import Tunable
from .database import Record, TuningDatabase, default_db, make_key, now
from .evaluate import Evaluator, WallClockEvaluator
from .params import Config, ParamSpace
from .platform import detect_platform
from .search import SearchAlgorithm, SearchResult, Trial, CoordinateDescent
from .search.base import INVALID

log = logging.getLogger("repro.tuner")


@dataclasses.dataclass
class TuningResult:
    best_config: Config
    best_objective: float
    default_objective: float          # the untuned baseline (paper's '-O3')
    evaluations: int
    search: SearchResult
    from_database: bool = False

    @property
    def speedup(self) -> float:
        if self.best_objective <= 0:
            return 1.0
        return self.default_objective / self.best_objective


def promoted_dtype(dtypes: Sequence[Any]) -> str:
    """Order-independent key dtype: the JAX type promotion of all array dtypes.

    Keying on any *single* argument's dtype makes mixed-dtype calls (bf16
    activations × f32 weights) produce argument-order-dependent database
    keys. The promoted dtype is symmetric in the arguments and names the
    precision the call actually computes in.

    Migration note: keys for mixed-dtype calls recorded before this change
    (which used the dtype of the *last* array argument — e.g. ``int32`` for
    softmax_xent's labels) will no longer hit; a campaign re-run or re-tune
    rebuilds them under the promoted-dtype key.
    """
    if not dtypes:
        return "f32"
    try:
        return _promote_cached(tuple(dtypes))
    except TypeError:          # unhashable dtype-likes: promote uncached
        import jax.numpy as jnp

        return str(jnp.result_type(*dtypes))


@functools.lru_cache(maxsize=512)
def _promote_cached(dtypes: tuple) -> str:
    # jnp.result_type costs ~25us; dispatch pays this per call, so memoize
    # on the (hashable) dtype tuple.
    import jax.numpy as jnp

    return str(jnp.result_type(*dtypes))


def _args_key(tunable: Tunable, args: Sequence[Any], platform: str, extra: str = "",
              dp_dims: Optional[Dict[int, int]] = None) -> str:
    """Database key for (tunable, concrete-or-traced args) on `platform`.

    Sharding-aware: inside a ``mesh_context`` that carries a ``dp_degree``
    (the Trainer's scope) the batch-leading args declared by the tunable's
    ``DispatchSpec.data_parallel_args`` are keyed on their per-device *local*
    shard shape (leading dim ÷ degree) — a jit trace carries global shapes,
    but each device executes the local shard, which is what a campaign
    tuned. Outside such a scope (serving warmup, campaign evaluation,
    tests, dry-run lowering) keys are unchanged.

    ``dp_dims`` (``{arg index: dim index}``) overrides the spec's
    leading-dim convention for THIS call: backward dispatch sites pass it
    when a transposed operand carries the token dim somewhere other than
    dim 0 (matmul's dL/dw keys ``x.T`` on dim 1).
    """
    shapes = []
    dtypes = []
    arg_dims: Dict[int, int] = {}
    spec = tunable.dispatch
    if dp_dims is None:
        dp_args = spec.data_parallel_args if spec is not None else (0,)
        dp_dims = {i: 0 for i in dp_args}
    for i, a in enumerate(args):
        if hasattr(a, "shape"):
            if i in dp_dims:
                arg_dims[len(shapes)] = dp_dims[i]
            shapes.append(tuple(a.shape))
            dtypes.append(getattr(a, "dtype", "float32"))
    shapes = _localize(shapes, arg_dims)
    key = make_key(tunable.name, platform, shapes, promoted_dtype(dtypes), extra)
    _warn_if_dp_approx(key)
    return key


def _warn_if_dp_approx(key: str) -> None:
    # ROADMAP-carried hazard, surfaced structurally: when the scope owner
    # flagged its dp_degree as approximate (microbatch batch dim divides the
    # mesh differently from the full input batch), the local-shape key we
    # just built may not match the shard XLA actually materializes. One
    # obs warning per key — recorded in the event buffer (and logged) even
    # when metric collection is disabled, never warnings.warn spam.
    from ..distributed.sharding import current_dp_approx

    if not current_dp_approx():
        return
    from ..obs.collect import warn_once

    warn_once(
        "dispatch.local_key_approx",
        key=key,
        detail=(
            "microbatch batch dim divides the mesh differently from the "
            "full input batch; local-shape key approximates XLA's shard"
        ),
    )


def _localize(shapes, arg_dims):
    # Late import: distributed is a higher layer; the ambient-context check
    # is a single contextvar read, so unsharded dispatch stays cheap.
    from ..distributed.sharding import localize_shapes

    return localize_shapes(shapes, batch_arg_dims=arg_dims)


def autotune(
    tunable: Tunable,
    args: Sequence[Any],
    search: Optional[SearchAlgorithm] = None,
    evaluator: Optional[Evaluator] = None,
    db: Optional[TuningDatabase] = None,
    key_extra: str = "",
    save: bool = True,
    seed_configs: Optional[Sequence[Config]] = None,
) -> TuningResult:
    """Full tuning pass for `tunable` on concrete `args`.

    `seed_configs` warm-start the search (transfer tuning): configs that won
    on a neighbouring shape bucket or sibling platform are evaluated first,
    so local strategies converge in far fewer evaluations than a cold start.
    Invalid seeds are silently dropped by the strategy.
    """
    search = search or CoordinateDescent(budget=48)
    evaluator = evaluator or WallClockEvaluator()
    platform = detect_platform().name

    # 1. Reference outputs (the correctness oracle).
    reference = None
    if tunable.reference is not None:
        reference = jax.jit(tunable.reference)(*args)
        jax.block_until_ready(reference)

    # Static legality pre-pass: configs whose abstract grid model is
    # infeasible on this platform (lane misalignment, OOB index map, racy
    # output ref) never reach compile+run — the Petrovič et al. 2019
    # "filter before measurement" step. Fail-open: a model-building error
    # must never block tuning, only skip the pruning.
    illegal: Dict[str, str] = {}
    try:
        from .gridmodel import space_illegal

        shapes = tuple(
            tuple(a.shape) for a in args if hasattr(a, "shape")
        )
        for ck, (cat, reason) in space_illegal(
            tunable.name, platform, shapes or None
        ).items():
            illegal[ck] = f"{cat}: {reason}"
    except Exception:                                 # pragma: no cover
        log.debug("legality pre-pass failed for %s", tunable.name, exc_info=True)

    # 2-4. Search with compile+run+gate per proposed config.
    def objective(config: Config) -> Trial:
        pruned = illegal.get(ParamSpace.config_key(config))
        if pruned is not None:
            log.debug("variant %s statically pruned: %s", config, pruned)
            return Trial(
                config=config, objective=INVALID, ok=False,
                meta={"pruned": pruned},
            )
        variant = tunable.variant(**config)
        m = evaluator.evaluate(variant, args, reference=reference)
        if not m.ok:
            log.debug("variant %s pruned: %s", config, m.error)
        return Trial(config=config, objective=m.objective, ok=m.ok, meta=m.meta)

    t0 = time.perf_counter()
    result = search.run(tunable.space, objective, seeds=tuple(seed_configs or ()))
    elapsed = time.perf_counter() - t0
    if result.best is None:
        raise RuntimeError(
            f"autotuning {tunable.name}: no valid variant found "
            f"({result.evaluations} evaluations)"
        )

    # Baseline: the default (heuristic) config = the 'unannotated' program.
    default_cfg = tunable.default_config(*args)
    base = evaluator.evaluate(tunable.variant(**default_cfg), args, reference=reference)
    default_obj = base.objective if base.ok else INVALID

    # The tuner must never regress (claim C3): a budget too small to rediscover
    # the baseline keeps the measured default as the winner.
    best_config, best_objective = result.best_config, result.best_objective
    if base.ok and tunable.space.is_valid(default_cfg) and default_obj < best_objective:
        best_config, best_objective = dict(default_cfg), default_obj

    # 5. Persist.
    if db is None:
        db = default_db()
    key = _args_key(tunable, args, platform, key_extra)
    db.put(
        Record(
            key=key,
            config=best_config,
            objective=best_objective,
            evaluator=evaluator.name,
            evaluations=result.evaluations,
            timestamp=now(),
            meta={
                "search": search.name,
                "default_objective": default_obj,
                "search_seconds": elapsed,
            },
        ),
        save=save,
    )
    log.info(
        "tuned %s: %.3gs -> %.3gs (%.2fx) in %d evals",
        key, default_obj, best_objective,
        (default_obj / best_objective if best_objective else 1.0),
        result.evaluations,
    )
    return TuningResult(
        best_config=best_config,
        best_objective=best_objective,
        default_objective=default_obj,
        evaluations=result.evaluations,
        search=result,
    )


def tune_or_lookup(
    tunable: Tunable,
    args: Sequence[Any],
    db: Optional[TuningDatabase] = None,
    allow_tune: bool = False,
    key_extra: str = "",
    allow_cover: bool = True,
    **tune_kwargs,
) -> Config:
    """Deployment-time config resolution.

    Precedence: exact DB hit > tune-now (`allow_tune`) > cover-set entry for
    the nearest tuned shape ('a few fit most': a small set of campaign
    winners covers most unseen buckets) > the shape heuristic default.
    """
    db = db or default_db()
    platform = detect_platform().name
    key = _args_key(tunable, args, platform, key_extra)
    rec = db.lookup(key)
    if rec is not None and tunable.space.is_valid(rec.config):
        return dict(rec.config)
    if allow_tune:
        return autotune(tunable, args, db=db, key_extra=key_extra, **tune_kwargs).best_config
    if allow_cover:
        shapes = [tuple(a.shape) for a in args if hasattr(a, "shape")]
        for entry in db.lookup_cover(tunable.name, platform, shapes):
            cfg = entry.get("config")
            if cfg is not None and tunable.space.is_valid(cfg):
                return dict(cfg)
    return tunable.default_config(*args)
