"""repro.core — annotation-based empirical autotuning (the paper's contribution).

Public API:

    from repro.core import (
        tunable, DispatchSpec, ParamSpace, PowerOfTwoParam, EnumParam,
        IntParam, BoolParam, Constraint, autotune, tune_or_lookup,
        TuningDatabase, default_db, make_search, WallClockEvaluator,
        CostModelEvaluator, detect_platform,
        # dispatch runtime (see core/runtime.py for the policy pipeline;
        # the `runtime(...)` factory lives at the top level: repro.runtime)
        TunedRuntime, current_runtime, dispatch, entry_point,
        ResolutionPolicy, ExactHit, TuneNow, CoverSet, Heuristic, Reference,
    )
"""
from .params import (
    BoolParam,
    Config,
    Constraint,
    EnumParam,
    IntParam,
    Param,
    ParamSpace,
    PowerOfTwoParam,
)
from .annotate import DispatchSpec, Tunable, get_tunable, registered, tunable
from .gridmodel import (
    GridModel,
    RefModel,
    config_verdict,
    register_grid_model,
    registered_models,
    space_illegal,
    space_report,
    sublanes_for,
)
from .database import (
    Record,
    TuningDatabase,
    default_db,
    make_key,
    set_default_db,
    shape_bucket,
    shape_distance,
    split_key,
)
from .evaluate import (
    CostModelEvaluator,
    Evaluator,
    Measurement,
    RooflineTerms,
    WallClockEvaluator,
    collective_stats,
    correctness_gate,
    roofline_from_compiled,
)
from .platform import (
    CPU_HOST,
    PROFILES,
    TPU_V4,
    TPU_V5E,
    HardwareProfile,
    detect_platform,
    platform_override,
    set_platform_override,
)
from .search import (
    ALGORITHMS,
    CoordinateDescent,
    ExhaustiveSearch,
    GeneticSearch,
    RandomSearch,
    SearchAlgorithm,
    SearchResult,
    SimulatedAnnealing,
    make_search,
)
from .tuner import TuningResult, autotune, promoted_dtype, tune_or_lookup
# NOTE: the `runtime(...)` factory itself is deliberately NOT imported here —
# binding that name in this package would shadow the `repro.core.runtime`
# submodule. Use `repro.runtime(...)` (top-level re-export) or
# `TunedRuntime(...)` directly.
from .runtime import (
    PHASES,
    CoverSet,
    ExactHit,
    Heuristic,
    Reference,
    Resolution,
    ResolutionPolicy,
    ResolutionRequest,
    Telemetry,
    TunedRuntime,
    TuneNow,
    current_phase,
    current_runtime,
    default_policy,
    dispatch,
    dispatch_phase,
    entry_point,
)
from .runtime import DispatchFault, HealthBook  # guarded execution
from .bgtune import BackgroundTune, BackgroundTuner, background_policy
