"""repro.core — annotation-based empirical autotuning (the paper's contribution).

Public API:

    from repro.core import (
        tunable, ParamSpace, PowerOfTwoParam, EnumParam, IntParam, BoolParam,
        Constraint, autotune, tune_or_lookup, TuningDatabase, default_db,
        make_search, WallClockEvaluator, CostModelEvaluator, detect_platform,
    )
"""
from .params import (
    BoolParam,
    Config,
    Constraint,
    EnumParam,
    IntParam,
    Param,
    ParamSpace,
    PowerOfTwoParam,
)
from .annotate import Tunable, get_tunable, registered, tunable
from .database import (
    Record,
    TuningDatabase,
    default_db,
    make_key,
    set_default_db,
    shape_bucket,
    shape_distance,
    split_key,
)
from .evaluate import (
    CostModelEvaluator,
    Evaluator,
    Measurement,
    RooflineTerms,
    WallClockEvaluator,
    collective_stats,
    correctness_gate,
    roofline_from_compiled,
)
from .platform import CPU_HOST, PROFILES, TPU_V4, TPU_V5E, HardwareProfile, detect_platform
from .search import (
    ALGORITHMS,
    CoordinateDescent,
    ExhaustiveSearch,
    GeneticSearch,
    RandomSearch,
    SearchAlgorithm,
    SearchResult,
    SimulatedAnnealing,
    make_search,
)
from .tuner import TuningResult, autotune, tune_or_lookup
