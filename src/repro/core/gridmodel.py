"""Abstract grid/BlockSpec models — the statically-checkable half of a kernel.

Every Pallas tunable in this repo is a *family* of kernels indexed by a
config: the config picks block shapes, the kernel derives a grid, index
maps, and ``dimension_semantics`` from them. Whether a config is *legal* on
a platform is a function of exactly those derived objects — not of the
kernel body — so legality can be decided without compiling or running
anything (Petrovič et al. 2019 filter infeasible configs the same way,
before measurement).

A kernel module registers a **grid builder**: a pure function
``build(config, shapes=None) -> GridModel | tuple[GridModel, ...] | None``
that mirrors the exact clamp/pad/grid arithmetic of the kernel entry point
(``None`` means the kernel itself would reject the shapes, e.g. flash
attention's divisibility asserts). Multi-pass kernels (xent backward's
lse+dl passes, flash backward's three passes) return one model per
``pallas_call``. The checks here then decide, per config × platform:

* **write-write races** — an output ref whose index map is *invariant*
  along a grid axis declared "parallel": two grid points would write the
  same block concurrently. This is the exact hazard class that forces
  ``rmsnorm_bwd``'s dw accumulator and ``ssm_scan``'s chunk carry onto
  sequential ("arbitrary") axes. Platform-independent → always an error.
* **index-map out-of-bounds** — a block index at any grid corner that maps
  outside the padded array dims. Platform-independent → always an error.
* **TPU tiling alignment** — when a block actually *tiles* an axis
  (block < padded dim), the last block dim must be a multiple of the lane
  count (128) and the second-to-last a multiple of the dtype sublane count
  (8 for f32, 16 for bf16). A block spanning the full dim is exempt (Mosaic
  pads whole arrays). Platform-dependent → these are *pruned configs*, not
  bugs.

``config_verdict`` / ``space_illegal`` / ``space_report`` are the low-level
API; ``ParamSpace.legal_configs(platform)`` (see ``params.py``) and the
``repro.analysis`` pass-2 checker are the two consumers. This module must
not import ``params`` — spaces link back to kernels via the
``_grid_kernels`` attribute that :func:`register_grid_model` attaches.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

from .platform import PROFILES, HardwareProfile, detect_platform

# ---------------------------------------------------------------------------
# Model structures
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RefModel:
    """One BlockSpec'd ref of a pallas_call: block shape + index map + dims.

    ``dims`` are the *padded* array dims the index map addresses (in
    block-index units: ``index_map(*grid_coord)[d]`` selects block
    ``idx[d]`` of size ``block[d]`` along an axis of extent ``dims[d]``).
    ``role`` distinguishes outputs (race-checked) from inputs. ``dtype``
    overrides the model dtype for refs that differ (e.g. int32 labels).
    """

    name: str
    block: Tuple[int, ...]
    index_map: Callable[..., Tuple[int, ...]]
    dims: Tuple[int, ...]
    role: str = "in"                 # "in" | "out"
    dtype: Optional[str] = None

    def __post_init__(self):
        if len(self.block) != len(self.dims):
            raise ValueError(
                f"ref {self.name!r}: block rank {len(self.block)} != "
                f"dims rank {len(self.dims)}"
            )


@dataclasses.dataclass(frozen=True)
class GridModel:
    """The abstract (grid, semantics, refs) triple of one pallas_call."""

    kernel: str
    grid: Tuple[int, ...]
    semantics: Tuple[str, ...]       # "parallel" | "arbitrary" per axis
    refs: Tuple[RefModel, ...]

    def __post_init__(self):
        if len(self.grid) != len(self.semantics):
            raise ValueError(
                f"{self.kernel}: grid rank {len(self.grid)} != "
                f"semantics rank {len(self.semantics)}"
            )

    def signature(self) -> Tuple:
        """Hashable identity of the *realized* kernel: configs with equal
        signatures compile to indistinguishable kernels at these shapes
        (the redundancy relation ``space_report`` counts)."""
        return (
            self.grid,
            tuple((r.name, r.block, r.dims) for r in self.refs),
        )


# ---------------------------------------------------------------------------
# Builder registry
# ---------------------------------------------------------------------------

BuildFn = Callable[..., Union[GridModel, Tuple[GridModel, ...], None]]


@dataclasses.dataclass(frozen=True)
class GridBuilder:
    kernel: str
    build: BuildFn
    space: Any = None                # the ParamSpace the kernel tunes over
    dtype: str = "float32"           # dtype the nominal shapes run at


_GRID_MODELS: Dict[str, GridBuilder] = {}


def register_grid_model(
    kernel: str,
    build: BuildFn,
    space: Any = None,
    dtype: str = "float32",
) -> None:
    """Declare the abstract grid model for a kernel tunable.

    Also links the kernel back onto ``space`` (via ``space._grid_kernels``)
    so ``ParamSpace.legal_configs`` can check a shared space against *every*
    kernel that tunes over it (e.g. RMSNORM_SPACE serves both ``rmsnorm``
    and ``rmsnorm_bwd`` — a config is legal iff legal under both).
    """
    _GRID_MODELS[kernel] = GridBuilder(kernel, build, space, dtype)
    if space is not None:
        kernels = getattr(space, "_grid_kernels", None)
        if kernels is None:
            kernels = []
            space._grid_kernels = kernels
        if kernel not in kernels:
            kernels.append(kernel)


def registered_models() -> Dict[str, GridBuilder]:
    return dict(_GRID_MODELS)


def build_models(
    kernel: str,
    config: Dict[str, Any],
    shapes: Optional[Sequence[Tuple[int, ...]]] = None,
) -> Optional[Tuple[GridModel, ...]]:
    """All pallas_call models the kernel realizes for this config (None if
    the kernel would reject the shapes outright)."""
    builder = _GRID_MODELS.get(kernel)
    if builder is None:
        return None
    try:
        out = builder.build(config, shapes)
    except Exception:
        return None
    if out is None:
        return None
    return out if isinstance(out, tuple) else (out,)


# ---------------------------------------------------------------------------
# Checks
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "float32": 4, "f32": 4, "float64": 8,
    "bfloat16": 2, "bf16": 2, "float16": 2, "f16": 2,
    "int64": 8, "int32": 4, "int16": 2, "int8": 1, "uint8": 1,
    "float8_e4m3fn": 1, "float8_e5m2": 1, "bool": 1,
}


def sublanes_for(profile: HardwareProfile, dtype: str) -> int:
    """Second-to-last-dim alignment for ``dtype`` on ``profile``.

    ``profile.sublanes`` is the fp32 (4-byte) figure; narrower dtypes pack
    more rows per physical sublane tile: 8×128 f32 → 16×128 bf16 → 32×128
    int8.
    """
    bytes_ = _DTYPE_BYTES.get(str(dtype), 4)
    return max(1, (profile.sublanes * 4) // bytes_)


def _grid_corners(grid: Tuple[int, ...]) -> List[Tuple[int, ...]]:
    return list(itertools.product(*({0, g - 1} for g in grid)))


def check_races(model: GridModel) -> Optional[str]:
    """Flag output refs invariant along a non-sequential grid axis.

    For each "parallel" axis, probe the index map at consecutive coordinates
    along that axis (holding others at a corner): if two *distinct* grid
    points map an output to the same block index, they write the same memory
    concurrently — a write-write race. Index-map coincidence along a
    parallel axis is the race, so this probe has no false positives;
    "arbitrary" axes execute sequentially and are exempt (that is exactly
    why rmsnorm_bwd's dw accumulator and ssm_scan's chunk carry declare
    their reduction axes "arbitrary").
    """
    n = len(model.grid)
    bases = [(0,) * n, tuple(g - 1 for g in model.grid)]
    for ref in model.refs:
        if ref.role != "out":
            continue
        for axis in range(n):
            if model.semantics[axis] != "parallel" or model.grid[axis] < 2:
                continue
            for base in bases:
                for j in range(min(model.grid[axis], 8) - 1):
                    a = list(base)
                    b = list(base)
                    a[axis], b[axis] = j, j + 1
                    ia = tuple(ref.index_map(*a))
                    ib = tuple(ref.index_map(*b))
                    if ia == ib:
                        return (
                            f"{model.kernel}: output ref {ref.name!r} is "
                            f"invariant along parallel grid axis {axis} "
                            f"(coords {tuple(a)} and {tuple(b)} both write "
                            f"block {ia}) — write-write race; declare the "
                            f"axis 'arbitrary' or index the output by it"
                        )
    return None


def check_oob(model: GridModel) -> Optional[str]:
    """Flag index maps that address blocks outside the padded array dims."""
    for ref in model.refs:
        for coord in _grid_corners(model.grid):
            idx = tuple(ref.index_map(*coord))
            if len(idx) != len(ref.block):
                return (
                    f"{model.kernel}: ref {ref.name!r} index map returns "
                    f"rank {len(idx)} for block rank {len(ref.block)}"
                )
            for d, (i, blk, dim) in enumerate(zip(idx, ref.block, ref.dims)):
                if i < 0 or (i + 1) * blk > dim:
                    return (
                        f"{model.kernel}: ref {ref.name!r} block index "
                        f"{idx} at grid coord {coord} spans "
                        f"[{i * blk}, {(i + 1) * blk}) outside padded dim "
                        f"{dim} on axis {d}"
                    )
    return None


def check_alignment(
    model: GridModel, profile: HardwareProfile, dtype: str = "float32"
) -> Optional[str]:
    """TPU lane/sublane tiling alignment (skipped off-TPU).

    Only axes a block actually *tiles* (block extent < padded dim) need
    alignment — a block spanning the full dim is laid out by Mosaic's
    whole-array padding and is always representable. For tiled axes, the
    last block dim must divide by the lane count and the second-to-last by
    the per-dtype sublane count; a second-to-minor extent of exactly 1 is
    a single sublane row and is also representable (the (1, block_q) lse
    row blocks of flash attention backward).
    """
    if not profile.name.startswith("tpu"):
        return None
    for ref in model.refs:
        dt = ref.dtype or dtype
        sub = sublanes_for(profile, dt)
        blk, dims = ref.block, ref.dims
        if len(blk) >= 1 and blk[-1] < dims[-1] and blk[-1] % profile.lanes:
            return (
                f"{model.kernel}: ref {ref.name!r} last block dim "
                f"{blk[-1]} tiles axis of {dims[-1]} but is not a multiple "
                f"of {profile.lanes} lanes ({profile.name})"
            )
        if len(blk) >= 2 and 1 < blk[-2] < dims[-2] and blk[-2] % sub:
            return (
                f"{model.kernel}: ref {ref.name!r} second-to-last block dim "
                f"{blk[-2]} tiles axis of {dims[-2]} but is not a multiple "
                f"of {sub} sublanes for {dt} ({profile.name})"
            )
    return None


def check_model(
    model: GridModel, profile: HardwareProfile, dtype: str = "float32"
) -> Optional[Tuple[str, str]]:
    """(category, reason) for the first failed check, in severity order:
    races and OOB are kernel bugs regardless of platform; alignment is a
    platform-specific infeasibility (a pruned config, not a bug)."""
    reason = check_races(model)
    if reason:
        return ("race", reason)
    reason = check_oob(model)
    if reason:
        return ("oob", reason)
    reason = check_alignment(model, profile, dtype)
    if reason:
        return ("align", reason)
    return None


# ---------------------------------------------------------------------------
# Space-level verdicts
# ---------------------------------------------------------------------------


def resolve_profile(
    platform: Union[str, HardwareProfile, None]
) -> HardwareProfile:
    if platform is None:
        return detect_platform()
    if isinstance(platform, HardwareProfile):
        return platform
    return PROFILES.get(platform) or detect_platform(platform)


def config_verdict(
    kernel: str,
    config: Dict[str, Any],
    platform: Union[str, HardwareProfile, None] = None,
    shapes: Optional[Sequence[Tuple[int, ...]]] = None,
) -> Optional[Tuple[str, str]]:
    """None if the config is legal for ``kernel`` on ``platform``, else the
    first (category, reason): 'build' | 'race' | 'oob' | 'align'."""
    builder = _GRID_MODELS.get(kernel)
    if builder is None:
        return None                  # no model declared → nothing to check
    profile = resolve_profile(platform)
    models = build_models(kernel, config, shapes)
    if models is None:
        return (
            "build",
            f"{kernel}: kernel rejects config {config} at these shapes",
        )
    for m in models:
        verdict = check_model(m, profile, builder.dtype)
        if verdict:
            return verdict
    return None


def space_illegal(
    kernel: str,
    platform: Union[str, HardwareProfile, None] = None,
    shapes: Optional[Sequence[Tuple[int, ...]]] = None,
) -> Dict[str, Tuple[str, str]]:
    """config_key → (category, reason) over the kernel's whole space."""
    builder = _GRID_MODELS.get(kernel)
    if builder is None or builder.space is None:
        return {}
    profile = resolve_profile(platform)
    out: Dict[str, Tuple[str, str]] = {}
    for cfg in builder.space.enumerate():
        verdict = config_verdict(kernel, cfg, profile, shapes)
        if verdict:
            out[builder.space.config_key(cfg)] = verdict
    return out


def space_report(
    kernel: str,
    platform: Union[str, HardwareProfile, None] = None,
    shapes: Optional[Sequence[Tuple[int, ...]]] = None,
) -> Dict[str, Any]:
    """Counts the pass-2 checker and ``campaign status`` report per kernel:
    total / legal / per-category illegal / redundant (configs whose realized
    models are signature-identical to a surviving config at these shapes)."""
    builder = _GRID_MODELS.get(kernel)
    profile = resolve_profile(platform)
    report: Dict[str, Any] = {
        "kernel": kernel,
        "platform": profile.name,
        "total": 0,
        "legal": 0,
        "illegal": 0,
        "by_category": {},
        "redundant": 0,
        "reasons": [],
    }
    if builder is None or builder.space is None:
        return report
    signatures = set()
    for cfg in builder.space.enumerate():
        report["total"] += 1
        verdict = config_verdict(kernel, cfg, profile, shapes)
        if verdict:
            cat, reason = verdict
            report["illegal"] += 1
            report["by_category"][cat] = report["by_category"].get(cat, 0) + 1
            if len(report["reasons"]) < 8:
                report["reasons"].append(reason)
            continue
        report["legal"] += 1
        models = build_models(kernel, cfg, shapes)
        sig = tuple(m.signature() for m in models) if models else None
        if sig is not None:
            if sig in signatures:
                report["redundant"] += 1
            signatures.add(sig)
    return report
