"""Persistent tuning database — the 'sustainable' half of the paper's title.

A tuning run is expensive (compile + run per variant); its *result* is a tiny
record. Persisting records keyed by ``(platform, kernel, shape-bucket,
dtype)`` is what turns one-off tuning into performance *portability*: ship
the generic code plus per-platform databases, and every installation looks up
(or lazily re-derives) its own specialization. A new machine ⇒ a new platform
key ⇒ a fresh tuning pass, never a silently-wrong reuse of another machine's
winners.

Shape bucketing: Figure 1 of the paper shows the best variant depends on the
input size, so records are keyed by shape — but exact-shape keys would never
hit in serving where shapes vary. We bucket each dim to the next power of two
(dims ≤ 8 kept exact), trading a little optimality for high hit rates.
"""
from __future__ import annotations

import dataclasses
import json
import logging
import math
import os
import tempfile
import threading
import time
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple, Union

# Stdlib-only import (no cycle): the named fault site "db.load:<path>" lets
# the chaos suite inject torn/corrupt reads deterministically.
from ..testing.faults import fault_point as _fault_point

log = logging.getLogger("repro.database")

SCHEMA_VERSION = 2


def atomic_write_json(path: str, blob: Dict[str, Any]) -> None:
    """Write-to-temp + rename so readers never see a torn file.

    Shared by the tuning database and the campaign manifest (same discipline
    as the checkpoint writer).
    """
    d = os.path.dirname(os.path.abspath(path))
    os.makedirs(d, exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(blob, f, indent=1, sort_keys=True)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def shape_bucket(shape: Sequence[int]) -> Tuple[int, ...]:
    out = []
    for d in shape:
        d = int(d)
        if d <= 8:
            out.append(d)
        else:
            p = 1
            while p < d:
                p <<= 1
            out.append(p)
    return tuple(out)


def make_key(
    kernel: str,
    platform: str,
    shapes: Sequence[Sequence[int]],
    dtype: str,
    extra: str = "",
) -> str:
    sh = "/".join("x".join(map(str, shape_bucket(s))) for s in shapes)
    key = f"{kernel}|{platform}|{sh}|{dtype}"
    if extra:
        key += f"|{extra}"
    return key


def split_key(key: str) -> Tuple[str, str, Tuple[Tuple[int, ...], ...], str, str]:
    """Inverse of :func:`make_key`: (kernel, platform, shapes, dtype, extra)."""
    parts = key.split("|")
    kernel, platform = parts[0], parts[1] if len(parts) > 1 else "?"
    shapes: Tuple[Tuple[int, ...], ...] = ()
    if len(parts) > 2 and parts[2]:
        shapes = tuple(
            tuple(int(d) for d in s.split("x") if d) for s in parts[2].split("/") if s
        )
    dtype = parts[3] if len(parts) > 3 else ""
    extra = "|".join(parts[4:]) if len(parts) > 4 else ""
    return kernel, platform, shapes, dtype, extra


def shape_distance(
    a: Sequence[Sequence[int]], b: Sequence[Sequence[int]]
) -> float:
    """Log2 distance between two bucketed shape tuples (transfer metric).

    Sum over all dims of |log2(a_d) - log2(b_d)|; infinite when ranks differ
    (a record for a different-rank call is not a meaningful neighbour).
    """
    if len(a) != len(b):
        return math.inf
    total = 0.0
    for sa, sb in zip(a, b):
        if len(sa) != len(sb):
            return math.inf
        for da, db in zip(sa, sb):
            da, db = max(int(da), 1), max(int(db), 1)
            total += abs(math.log2(da) - math.log2(db))
    return total


@dataclasses.dataclass
class Record:
    key: str
    config: Dict[str, Any]
    objective: float                  # seconds (lower is better)
    evaluator: str                    # 'wallclock' | 'costmodel'
    evaluations: int                  # search cost that produced this record
    timestamp: float
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(d: Dict[str, Any]) -> "Record":
        return Record(**d)


class TuningDatabase:
    """JSON-file-backed store with atomic writes and an in-memory cache.

    Concurrency model: many readers, single writer per process (a lock guards
    mutation); cross-process safety comes from write-to-temp + atomic rename,
    the same discipline the checkpoint writer uses.
    """

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self._lock = threading.Lock()
        self._records: Dict[str, Record] = {}
        # Cover sets: "kernel|platform" -> ordered list of
        # {"config": {...}, "support": [[dims...],...], "share": float}
        # entries — the 'few fit most' fallback for unseen shape buckets.
        self._covers: Dict[str, List[Dict[str, Any]]] = {}
        if path and os.path.exists(path):
            self._load()

    # -- io -----------------------------------------------------------------
    def _load(self) -> None:
        # A torn/corrupt file must degrade, not crash: our own writers are
        # atomic (write-to-temp + rename), but an external copy, a partial
        # scp, or a dying disk can still hand us garbage — and a tuning db
        # is always recoverable by re-tuning. Same contract as the schema
        # check below: warn, start empty.
        try:
            _fault_point(f"db.load:{self.path}")
            with open(self.path) as f:
                blob = json.load(f)
        except (ValueError, OSError) as e:
            log.warning(
                "tuning db %s unreadable (%s: %s); starting with empty "
                "records (a fresh tuning pass will rebuild them)",
                self.path, type(e).__name__, e,
            )
            self._records = {}
            self._covers = {}
            return
        if blob.get("schema", 0) != SCHEMA_VERSION:
            # Old schema: start fresh rather than misread stale records.
            log.warning(
                "tuning db %s has schema %s != %s; ignoring its records "
                "(a fresh tuning pass will rebuild them)",
                self.path, blob.get("schema", 0), SCHEMA_VERSION,
            )
            self._records = {}
            self._covers = {}
            return
        self._records = {
            k: Record.from_json(v) for k, v in blob.get("records", {}).items()
        }
        self._covers = dict(blob.get("covers", {}))

    def save(self) -> None:
        if not self.path:
            return
        blob = {
            "schema": SCHEMA_VERSION,
            "records": {k: r.to_json() for k, r in self._records.items()},
        }
        if self._covers:
            blob["covers"] = self._covers
        atomic_write_json(self.path, blob)

    # -- access ---------------------------------------------------------------
    def lookup(self, key: str) -> Optional[Record]:
        return self._records.get(key)

    def put(self, record: Record, save: bool = True) -> None:
        with self._lock:
            prev = self._records.get(record.key)
            # Keep the better record — a re-tune that regressed (noise) must
            # not clobber a good stored winner.
            if prev is None or record.objective <= prev.objective:
                self._records[record.key] = record
            if save:
                self.save()

    def keys(self) -> Iterable[str]:
        return list(self._records)

    def records(self) -> List[Record]:
        return list(self._records.values())

    def __len__(self) -> int:
        return len(self._records)

    def platforms(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for k in self._records:
            plat = k.split("|")[1] if "|" in k else "?"
            out[plat] = out.get(plat, 0) + 1
        return out

    # -- cover sets ('a few fit most') ---------------------------------------
    @staticmethod
    def cover_key(kernel: str, platform: str) -> str:
        return f"{kernel}|{platform}"

    def covers(self) -> Dict[str, List[Dict[str, Any]]]:
        """All stored cover sets, keyed "kernel|platform"."""
        return {k: [dict(e) for e in v] for k, v in self._covers.items()}

    def put_cover(
        self,
        kernel: str,
        platform: str,
        entries: Sequence[Dict[str, Any]],
        save: bool = True,
    ) -> None:
        """Store the clustered cover set for (kernel, platform).

        Each entry is {"config", "support", "share"}: a winning config, the
        bucketed shape tuples it won on, and the fraction of tuned keys it
        covers. Entries are kept in descending-share order so lookup's first
        valid entry is the broadest specialization.
        """
        with self._lock:
            self._covers[self.cover_key(kernel, platform)] = [
                dict(e) for e in entries
            ]
            if save:
                self.save()

    def lookup_cover(
        self,
        kernel: str,
        platform: str,
        shapes: Optional[Sequence[Sequence[int]]] = None,
    ) -> List[Dict[str, Any]]:
        """Cover entries for (kernel, platform), best-first for `shapes`.

        With `shapes`, entries are re-ranked by the minimum log2 distance
        between the query's shape buckets and each entry's support set, so an
        unseen shape lands on the specialization tuned for its nearest
        neighbours; ties keep the descending-share order.
        """
        entries = self._covers.get(self.cover_key(kernel, platform), [])
        if not entries:
            return []
        if shapes is None:
            return [dict(e) for e in entries]
        q = tuple(shape_bucket(s) for s in shapes)

        def dist(entry: Dict[str, Any]) -> float:
            support = entry.get("support") or []
            ds = [shape_distance(q, [tuple(dim) for dim in sup]) for sup in support]
            ds = [d for d in ds if d < math.inf]
            return min(ds) if ds else math.inf

        order = sorted(range(len(entries)), key=lambda i: (dist(entries[i]), i))
        return [dict(entries[i]) for i in order]

    # -- bulk operations ------------------------------------------------------
    def merge(
        self,
        other: Union["TuningDatabase", Iterable[Record]],
        save: bool = True,
    ) -> int:
        """Fold another database (or an iterable of records) into this one.

        Better-record-wins per key, same as :meth:`put`; cover sets from the
        other database overwrite ours key-by-key (they are derived data and
        the incoming campaign is assumed fresher). Returns the number of
        records that were accepted (new or improved).
        """
        if isinstance(other, TuningDatabase):
            records: Iterable[Record] = other.records()
            covers = other._covers
        else:
            records, covers = other, {}
        accepted = 0
        for rec in records:
            prev = self._records.get(rec.key)
            if prev is None or rec.objective <= prev.objective:
                accepted += 1
            self.put(rec, save=False)
        with self._lock:
            self._covers.update({k: [dict(e) for e in v] for k, v in covers.items()})
            if save:
                self.save()
        return accepted

    def export(
        self, path: str, platform: Optional[str] = None
    ) -> "TuningDatabase":
        """Write a standalone database at `path` (optionally one platform).

        This is the paper's shippable artifact: generic code + this file is a
        deployment for `platform`. Covers ride along so unseen shapes fall
        back to the campaign's 'few fit most' set rather than the heuristic.
        """
        out = TuningDatabase(None)
        for rec in self.records():
            if platform is None or split_key(rec.key)[1] == platform:
                out.put(rec, save=False)
        out._covers = {
            k: [dict(e) for e in v]
            for k, v in self._covers.items()
            if platform is None or k.split("|")[-1] == platform
        }
        out.path = path
        out.save()
        return out


_default_db: Optional[TuningDatabase] = None


def default_db() -> TuningDatabase:
    """Process-wide database at $REPRO_TUNING_DB (or .repro_tuning.json)."""
    global _default_db
    if _default_db is None:
        path = os.environ.get("REPRO_TUNING_DB", ".repro_tuning.json")
        _default_db = TuningDatabase(path)
    return _default_db


def set_default_db(db: TuningDatabase) -> None:
    global _default_db
    _default_db = db


def now() -> float:
    return time.time()
