"""Persistent tuning database — the 'sustainable' half of the paper's title.

A tuning run is expensive (compile + run per variant); its *result* is a tiny
record. Persisting records keyed by ``(platform, kernel, shape-bucket,
dtype)`` is what turns one-off tuning into performance *portability*: ship
the generic code plus per-platform databases, and every installation looks up
(or lazily re-derives) its own specialization. A new machine ⇒ a new platform
key ⇒ a fresh tuning pass, never a silently-wrong reuse of another machine's
winners.

Shape bucketing: Figure 1 of the paper shows the best variant depends on the
input size, so records are keyed by shape — but exact-shape keys would never
hit in serving where shapes vary. We bucket each dim to the next power of two
(dims ≤ 8 kept exact), trading a little optimality for high hit rates.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import threading
import time
from typing import Any, Dict, Iterable, Optional, Sequence, Tuple

SCHEMA_VERSION = 2


def shape_bucket(shape: Sequence[int]) -> Tuple[int, ...]:
    out = []
    for d in shape:
        d = int(d)
        if d <= 8:
            out.append(d)
        else:
            p = 1
            while p < d:
                p <<= 1
            out.append(p)
    return tuple(out)


def make_key(
    kernel: str,
    platform: str,
    shapes: Sequence[Sequence[int]],
    dtype: str,
    extra: str = "",
) -> str:
    sh = "/".join("x".join(map(str, shape_bucket(s))) for s in shapes)
    key = f"{kernel}|{platform}|{sh}|{dtype}"
    if extra:
        key += f"|{extra}"
    return key


@dataclasses.dataclass
class Record:
    key: str
    config: Dict[str, Any]
    objective: float                  # seconds (lower is better)
    evaluator: str                    # 'wallclock' | 'costmodel'
    evaluations: int                  # search cost that produced this record
    timestamp: float
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self)

    @staticmethod
    def from_json(d: Dict[str, Any]) -> "Record":
        return Record(**d)


class TuningDatabase:
    """JSON-file-backed store with atomic writes and an in-memory cache.

    Concurrency model: many readers, single writer per process (a lock guards
    mutation); cross-process safety comes from write-to-temp + atomic rename,
    the same discipline the checkpoint writer uses.
    """

    def __init__(self, path: Optional[str] = None):
        self.path = path
        self._lock = threading.Lock()
        self._records: Dict[str, Record] = {}
        if path and os.path.exists(path):
            self._load()

    # -- io -----------------------------------------------------------------
    def _load(self) -> None:
        with open(self.path) as f:
            blob = json.load(f)
        if blob.get("schema", 0) != SCHEMA_VERSION:
            # Old schema: start fresh rather than misread stale records.
            self._records = {}
            return
        self._records = {
            k: Record.from_json(v) for k, v in blob.get("records", {}).items()
        }

    def save(self) -> None:
        if not self.path:
            return
        blob = {
            "schema": SCHEMA_VERSION,
            "records": {k: r.to_json() for k, r in self._records.items()},
        }
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(blob, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)
        finally:
            if os.path.exists(tmp):
                os.unlink(tmp)

    # -- access ---------------------------------------------------------------
    def lookup(self, key: str) -> Optional[Record]:
        return self._records.get(key)

    def put(self, record: Record, save: bool = True) -> None:
        with self._lock:
            prev = self._records.get(record.key)
            # Keep the better record — a re-tune that regressed (noise) must
            # not clobber a good stored winner.
            if prev is None or record.objective <= prev.objective:
                self._records[record.key] = record
            if save:
                self.save()

    def keys(self) -> Iterable[str]:
        return list(self._records)

    def __len__(self) -> int:
        return len(self._records)

    def platforms(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for k in self._records:
            plat = k.split("|")[1] if "|" in k else "?"
            out[plat] = out.get(plat, 0) + 1
        return out


_default_db: Optional[TuningDatabase] = None


def default_db() -> TuningDatabase:
    """Process-wide database at $REPRO_TUNING_DB (or .repro_tuning.json)."""
    global _default_db
    if _default_db is None:
        path = os.environ.get("REPRO_TUNING_DB", ".repro_tuning.json")
        _default_db = TuningDatabase(path)
    return _default_db


def set_default_db(db: TuningDatabase) -> None:
    global _default_db
    _default_db = db


def now() -> float:
    return time.time()
