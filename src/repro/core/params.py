"""Tunable parameter spaces — the search-space half of the paper's "performance
directives".

In Orio, an annotation like ``@PerfTuning(unroll_factor in [1..8], ...)``
declares a cartesian product of discrete knobs plus validity constraints.
This module is that declaration language for JAX/Pallas: each
:class:`Param` is one knob, a :class:`ParamSpace` is the cartesian product
with cross-knob :class:`Constraint`s (e.g. "tile working set must fit VMEM").

Spaces are deliberately *discrete and finite* — empirical autotuning compiles
and runs variants, so the space must be enumerable (exhaustively for small
spaces, by guided search for large ones; see ``core/search``).
"""
from __future__ import annotations

import dataclasses
import itertools
import math
import random
from typing import Any, Callable, Dict, Iterator, List, Mapping, Optional, Sequence, Tuple

Config = Dict[str, Any]


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Param:
    """A single named knob with a finite ordered domain."""

    name: str
    choices: Tuple[Any, ...]

    def __post_init__(self):
        if not self.choices:
            raise ValueError(f"param {self.name!r} has an empty domain")
        if len(set(map(repr, self.choices))) != len(self.choices):
            raise ValueError(f"param {self.name!r} has duplicate choices")

    # Domain helpers -------------------------------------------------------
    @property
    def cardinality(self) -> int:
        return len(self.choices)

    def index_of(self, value: Any) -> int:
        try:
            return self.choices.index(value)
        except ValueError:
            raise KeyError(
                f"value {value!r} not in domain of param {self.name!r}"
            ) from None

    def neighbors(self, value: Any) -> List[Any]:
        """Adjacent choices in domain order (the coordinate-descent moves)."""
        i = self.index_of(value)
        out = []
        if i > 0:
            out.append(self.choices[i - 1])
        if i + 1 < len(self.choices):
            out.append(self.choices[i + 1])
        return out

    def sample(self, rng: random.Random) -> Any:
        return rng.choice(self.choices)


def IntParam(name: str, choices: Sequence[int]) -> Param:
    return Param(name, tuple(int(c) for c in choices))


def PowerOfTwoParam(name: str, lo: int, hi: int) -> Param:
    """Powers of two in [lo, hi] inclusive — the canonical tile-size domain."""
    if lo <= 0 or hi < lo:
        raise ValueError(f"bad power-of-two range [{lo}, {hi}]")
    start = 1 << max(0, math.ceil(math.log2(lo)))
    vals = []
    v = start
    while v <= hi:
        vals.append(v)
        v <<= 1
    if not vals:
        raise ValueError(f"no powers of two in [{lo}, {hi}]")
    return Param(name, tuple(vals))


def EnumParam(name: str, choices: Sequence[Any]) -> Param:
    return Param(name, tuple(choices))


def BoolParam(name: str) -> Param:
    return Param(name, (False, True))


# ---------------------------------------------------------------------------
# Constraints
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Constraint:
    """A validity predicate over a full config (Orio's `constraint=` clause).

    ``fn`` receives the config dict and must return truthiness. ``reason`` is
    used in diagnostics when a search space turns out to be empty.
    """

    fn: Callable[[Config], bool]
    reason: str = "constraint"

    def __call__(self, config: Config) -> bool:
        return bool(self.fn(config))


# ---------------------------------------------------------------------------
# Space
# ---------------------------------------------------------------------------


class ParamSpace:
    """Cartesian product of :class:`Param`s filtered by :class:`Constraint`s."""

    def __init__(
        self,
        params: Sequence[Param],
        constraints: Sequence[Constraint] = (),
    ):
        names = [p.name for p in params]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate param names: {names}")
        self.params: Tuple[Param, ...] = tuple(params)
        self.constraints: Tuple[Constraint, ...] = tuple(constraints)
        self._by_name = {p.name: p for p in self.params}

    # -- basic introspection ------------------------------------------------
    @property
    def names(self) -> Tuple[str, ...]:
        return tuple(p.name for p in self.params)

    def __getitem__(self, name: str) -> Param:
        return self._by_name[name]

    @property
    def cardinality(self) -> int:
        """Size of the *unconstrained* product (upper bound on variants)."""
        n = 1
        for p in self.params:
            n *= p.cardinality
        return n

    # -- validity -----------------------------------------------------------
    def is_valid(self, config: Config) -> bool:
        if set(config) != set(self.names):
            return False
        for p in self.params:
            if config[p.name] not in p.choices:
                return False
        return all(c(config) for c in self.constraints)

    def why_invalid(self, config: Config) -> Optional[str]:
        if set(config) != set(self.names):
            return f"keys {sorted(config)} != space {sorted(self.names)}"
        for p in self.params:
            if config[p.name] not in p.choices:
                return f"{p.name}={config[p.name]!r} not in domain"
        for c in self.constraints:
            if not c(config):
                return c.reason
        return None

    # -- enumeration / sampling ----------------------------------------------
    def enumerate(self) -> Iterator[Config]:
        """All valid configs, in deterministic lexicographic order."""
        for combo in itertools.product(*(p.choices for p in self.params)):
            cfg = dict(zip(self.names, combo))
            if all(c(cfg) for c in self.constraints):
                yield cfg

    def sample(self, rng: random.Random, max_tries: int = 1000) -> Config:
        """One uniformly-ish random valid config (rejection sampling)."""
        for _ in range(max_tries):
            cfg = {p.name: p.sample(rng) for p in self.params}
            if all(c(cfg) for c in self.constraints):
                return cfg
        # Fall back to scanning — guarantees progress on tight constraints.
        valid = list(itertools.islice(self.enumerate(), 10000))
        if not valid:
            raise RuntimeError(
                "search space is empty: "
                + "; ".join(c.reason for c in self.constraints)
            )
        return rng.choice(valid)

    def neighbors(self, config: Config) -> List[Config]:
        """Valid one-knob-step neighbors (the hillclimb/annealing move set)."""
        out: List[Config] = []
        for p in self.params:
            for v in p.neighbors(config[p.name]):
                cand = dict(config)
                cand[p.name] = v
                if all(c(cand) for c in self.constraints):
                    out.append(cand)
        return out

    def random_neighbor(self, config: Config, rng: random.Random) -> Config:
        nbrs = self.neighbors(config)
        return rng.choice(nbrs) if nbrs else dict(config)

    def crossover(self, a: Config, b: Config, rng: random.Random) -> Config:
        """Uniform crossover (genetic search); falls back to `a` if invalid."""
        for _ in range(32):
            child = {
                name: (a if rng.random() < 0.5 else b)[name] for name in self.names
            }
            if all(c(child) for c in self.constraints):
                return child
        return dict(a)

    # -- canonical keys -------------------------------------------------------
    @staticmethod
    def config_key(config: Config) -> str:
        """Stable string key for a config (database + dedup)."""
        return ",".join(f"{k}={config[k]}" for k in sorted(config))

    def default(self) -> Config:
        """First valid config in enumeration order — the 'untuned' baseline."""
        for cfg in self.enumerate():
            return cfg
        raise RuntimeError("search space is empty")

    def legal_configs(
        self,
        platform: Any = None,
        shapes: Optional[Sequence[Tuple[int, ...]]] = None,
    ) -> List[Config]:
        """Valid configs that are also *statically legal* on ``platform``.

        Constraints (above) encode what the search space's author knew;
        legality is derived from the kernels' abstract grid models
        (:mod:`repro.core.gridmodel`): TPU lane/sublane alignment, index-map
        bounds, and write-write race freedom, evaluated at ``shapes`` (or
        each kernel's nominal shapes). A space shared by several kernels
        (e.g. rmsnorm fwd + bwd) keeps a config only if it is legal under
        *every* linked kernel — the campaign scheduler prunes with this
        before spending measurement budget. Spaces with no Pallas grid
        model behind them (model-level chunk knobs, jnp-only backward
        spaces) are returned in full.
        """
        kernels = getattr(self, "_grid_kernels", ())
        if not kernels:
            return list(self.enumerate())
        from .gridmodel import config_verdict, resolve_profile

        profile = resolve_profile(platform)
        out: List[Config] = []
        for cfg in self.enumerate():
            if all(
                config_verdict(k, cfg, profile, shapes) is None
                for k in kernels
            ):
                out.append(cfg)
        return out

    def __repr__(self) -> str:
        ps = ", ".join(f"{p.name}[{p.cardinality}]" for p in self.params)
        return f"ParamSpace({ps}; |product|={self.cardinality})"
