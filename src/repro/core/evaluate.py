"""Variant evaluators + the correctness gate.

The paper's loop is: transform → compile → execute → compare-with-reference →
keep metrics. An :class:`Evaluator` implements 'compile → execute → metrics'
for one platform; :func:`correctness_gate` implements 'compare with
reference'. Two evaluators:

* :class:`WallClockEvaluator` — empirically times the jitted variant on this
  process's devices (the paper's own method; used on CPU for kernels and jnp
  paths).
* :class:`CostModelEvaluator` — for the TPU target we cannot execute on:
  lowers + compiles the variant for a (possibly fake-device) mesh and scores
  it by its dominant roofline term, derived from ``cost_analysis()`` plus
  collective bytes parsed out of the compiled HLO. This is the evaluator the
  sharding-layout tuning uses; it is also the §Roofline machinery.
"""
from __future__ import annotations

import dataclasses
import math
import re
import time
from typing import Any, Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .platform import HardwareProfile, TPU_V5E

# ---------------------------------------------------------------------------
# Correctness gate
# ---------------------------------------------------------------------------

_TOL = {
    jnp.float32.dtype: (1e-5, 1e-5),
    jnp.bfloat16.dtype: (2e-2, 2e-2),
    jnp.float16.dtype: (1e-2, 1e-2),
}


def tolerance_for(dtype) -> Tuple[float, float]:
    return _TOL.get(jnp.dtype(dtype), (1e-5, 1e-5))


def correctness_gate(out, ref, rtol: Optional[float] = None, atol: Optional[float] = None) -> bool:
    """True iff `out` matches the reference pytree within dtype tolerance.

    Structure, shape, and NaN discipline: mismatched tree *structures* fail
    even when leaf counts happen to agree; a NaN in `out` where the
    reference is finite fails; NaNs in the same positions as reference NaNs
    pass (the reference defines them as expected). Tolerance is dtype-aware
    — the coarser of the two leaves' dtypes decides (a bf16 variant judged
    against an f32 reference gets bf16 tolerance), evaluated *before* the
    float32 upcast used for comparison. Zero-size leaves trivially pass.
    """
    if jax.tree_util.tree_structure(out) != jax.tree_util.tree_structure(ref):
        return False
    outs = jax.tree_util.tree_leaves(out)
    refs = jax.tree_util.tree_leaves(ref)
    for o, r in zip(outs, refs):
        if rtol is not None:
            rt, at = rtol, atol
        else:
            rt_o, at_o = tolerance_for(getattr(o, "dtype", np.float32))
            rt_r, at_r = tolerance_for(getattr(r, "dtype", np.float32))
            rt, at = max(rt_o, rt_r), max(at_o, at_r)
        o = np.asarray(o, dtype=np.float32)
        r = np.asarray(r, dtype=np.float32)
        if o.shape != r.shape:
            return False
        if not r.size:
            continue
        scale = max(1.0, float(np.max(np.abs(r[np.isfinite(r)]), initial=0.0)))
        if np.any(np.isnan(o) & ~np.isnan(r)):
            return False
        if not np.allclose(o, r, rtol=rt or 1e-5, atol=(at or 1e-5) * scale,
                           equal_nan=True):
            return False
    return True


# ---------------------------------------------------------------------------
# Measurements
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Measurement:
    objective: float             # seconds, lower is better; inf on failure
    ok: bool
    error: Optional[str] = None
    meta: Dict[str, Any] = dataclasses.field(default_factory=dict)


class Evaluator:
    name = "base"

    def evaluate(self, fn: Callable, args: Sequence[Any], reference=None) -> Measurement:
        raise NotImplementedError


class WallClockEvaluator(Evaluator):
    """Median-of-k wall time of the jitted variant (after compile + warmup).

    This is the paper's measurement, verbatim: each variant is compiled,
    executed, timed, and its output compared to the reference output.
    """

    name = "wallclock"

    def __init__(self, repeats: int = 5, warmup: int = 2, rtol=None, atol=None):
        self.repeats = repeats
        self.warmup = warmup
        self.rtol = rtol
        self.atol = atol

    def evaluate(self, fn: Callable, args: Sequence[Any], reference=None) -> Measurement:
        try:
            jfn = jax.jit(fn)
            out = jfn(*args)
            jax.block_until_ready(out)
        except Exception as e:  # invalid variant (bad tile, OOM, ...) — prune
            return Measurement(math.inf, False, error=f"{type(e).__name__}: {e}")

        if reference is not None and not correctness_gate(out, reference, self.rtol, self.atol):
            return Measurement(math.inf, False, error="correctness gate failed")

        for _ in range(self.warmup):
            jax.block_until_ready(jfn(*args))
        times = []
        for _ in range(self.repeats):
            t0 = time.perf_counter()
            jax.block_until_ready(jfn(*args))
            times.append(time.perf_counter() - t0)
        times.sort()
        med = times[len(times) // 2]
        return Measurement(med, True, meta={"times": times, "best": times[0]})


# ---------------------------------------------------------------------------
# HLO analysis: flops / bytes / collective bytes  (shared with §Roofline)
# ---------------------------------------------------------------------------

_COLLECTIVE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\b",
)
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8e4m3fn|f8e5m2|s32|u32|s16|u16|s8|u8|pred|s64|u64)\[([\d,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "s64": 8, "u64": 8,
    "f32": 4, "s32": 4, "u32": 4,
    "bf16": 2, "f16": 2, "s16": 2, "u16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "s8": 1, "u8": 1, "pred": 1,
}

_COMP_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\([^)]*")
_WHILE_RE = re.compile(
    r"\bwhile\(.*?\),\s*condition=%?([\w.\-]+),\s*body=%?([\w.\-]+)"
)
_CALL_RE = re.compile(r"\b(?:call|conditional)\(.*?\).*?to_apply=%?([\w.\-]+)")
_CONST_RE = re.compile(r"%?([\w.\-]+)\s*=\s*s(?:32|64)\[\]\s*constant\((\d+)\)")
_CMP_RE = re.compile(
    r"compare\(\s*%?([\w.\-]+),\s*%?([\w.\-]+)\s*\),\s*direction=(LT|LE|GT|GE)"
)


def _shape_bytes(shape_str: str) -> int:
    """Sum of tensor bytes in an HLO result-shape string (handles tuples)."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_computations(hlo_text: str) -> Dict[str, list]:
    """Map computation name -> list of body lines. Entry stored as '__entry__'."""
    comps: Dict[str, list] = {}
    cur = None
    for line in hlo_text.splitlines():
        stripped = line.rstrip()
        if stripped.endswith("{") and ("->" in stripped or stripped.startswith("ENTRY")):
            m = _COMP_HEADER_RE.match(stripped)
            if m:
                name = m.group(1)
                if stripped.startswith("ENTRY"):
                    comps["__entry__"] = []
                    comps[name] = comps["__entry__"]
                    cur = name
                else:
                    comps[name] = []
                    cur = name
                continue
        if cur is not None:
            if stripped == "}":
                cur = None
            else:
                comps[cur].append(line)
    return comps


def _trip_count(cond_lines: list) -> int:
    """Extract the loop trip count from a while-condition computation."""
    consts = {}
    for line in cond_lines:
        m = _CONST_RE.search(line)
        if m:
            consts[m.group(1)] = int(m.group(2))
    for line in cond_lines:
        m = _CMP_RE.search(line)
        if m:
            a, b, d = m.groups()
            c = consts.get(b, consts.get(a))
            if c is not None:
                return c + 1 if d in ("LE", "GE") else c
    # fallback: largest plausible integer constant
    vals = [v for v in consts.values() if 1 <= v <= 10_000_000]
    return max(vals) if vals else 1


def collective_stats(hlo_text: str) -> Dict[str, Any]:
    """Trip-count-aware per-kind byte totals of every collective.

    XLA cost analysis visits while-loop bodies ONCE, which silently drops a
    ~num_layers× factor for scanned models. This walks the computation call
    graph from ENTRY, multiplying collective bytes inside each while body by
    its parsed trip count (nested loops compose multiplicatively). Bytes are
    result-shape bytes; async -start ops count the largest tuple element to
    avoid double-counting operand aliases.
    """
    comps = _split_computations(hlo_text)

    raw: Dict[str, Dict[str, int]] = {}
    calls: Dict[str, list] = {}
    for name, lines in comps.items():
        if name == "__entry__":
            continue
        kinds: Dict[str, int] = {}
        sub = []
        for line in lines:
            mc = _COLLECTIVE_RE.match(line)
            if mc:
                shape_str, kind, is_start = mc.group(1), mc.group(2), mc.group(3)
                b = _shape_bytes(shape_str)
                if is_start and shape_str.startswith("("):
                    elems = [_shape_bytes(s) for s in re.findall(r"\w+\[[\d,]*\]", shape_str)]
                    b = max(elems) if elems else b
                kinds[kind] = kinds.get(kind, 0) + b
                continue
            mw = _WHILE_RE.search(line)
            if mw:
                cond, body = mw.groups()
                sub.append((body, _trip_count(comps.get(cond, []))))
                continue
            for mcall in _CALL_RE.finditer(line):
                sub.append((mcall.group(1), 1))
        raw[name] = kinds
        calls[name] = sub

    memo: Dict[str, Dict[str, int]] = {}

    def total(name: str, depth=0) -> Dict[str, int]:
        if name in memo or depth > 64:
            return memo.get(name, {})
        out = dict(raw.get(name, {}))
        for child, trips in calls.get(name, []):
            for k, v in total(child, depth + 1).items():
                out[k] = out.get(k, 0) + v * trips
        memo[name] = out
        return out

    # entry name: the computation aliased to __entry__
    entry_kinds: Dict[str, int] = {}
    for name in comps:
        if name != "__entry__" and comps[name] is comps["__entry__"]:
            entry_kinds = total(name)
            break

    flat_count = sum(
        1
        for name, lines in comps.items()
        if name != "__entry__"
        for line in lines
        if _COLLECTIVE_RE.match(line)
    )

    return {
        "bytes_by_kind": entry_kinds,
        "total_bytes": sum(entry_kinds.values()),
        "count": flat_count,
    }


@dataclasses.dataclass
class RooflineTerms:
    """The three §Roofline terms, in seconds, for one compiled step."""

    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    hlo_bytes: float
    collective_bytes: float
    chips: int

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """Lower-bound step time: max of the three terms (perfect overlap)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    def to_json(self) -> Dict[str, Any]:
        return dataclasses.asdict(self) | {
            "dominant": self.dominant,
            "step_time_s": self.step_time_s,
        }


def roofline_from_compiled(
    compiled,
    profile: HardwareProfile = TPU_V5E,
    chips: Optional[int] = None,
    hlo_text: Optional[str] = None,
) -> RooflineTerms:
    """Derive the three roofline terms from a compiled executable.

    cost_analysis() reports whole-program FLOPs/bytes (already per the SPMD
    module, i.e. per device). Collective bytes come from the HLO text. The
    collective term divides by links-per-chip≈1 conservative model: bytes on
    the busiest kind / link bandwidth.
    """
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # older jax returns [dict]
        ca = ca[0]
    flops = float(ca.get("flops", 0.0))
    bytes_accessed = float(ca.get("bytes accessed", ca.get("bytes_accessed", 0.0)))
    n = chips or len(jax.devices())
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_stats(text)
    coll_bytes = float(coll["total_bytes"])
    return RooflineTerms(
        compute_s=flops / profile.peak_flops_bf16,
        memory_s=bytes_accessed / profile.hbm_bandwidth,
        collective_s=coll_bytes / profile.ici_bandwidth,
        flops=flops,
        hlo_bytes=bytes_accessed,
        collective_bytes=coll_bytes,
        chips=n,
    )


class CostModelEvaluator(Evaluator):
    """Score a variant by lowering+compiling it and taking the roofline bound.

    `fn` must be a zero-arg thunk returning a `jax.stages.Compiled` (the
    tuner wires mesh/shardings/ShapeDtypeStructs into the thunk). Objective =
    max(compute, memory, collective) seconds — the overlap-optimistic step
    bound; minimizing it is minimizing the dominant term, which is the §Perf
    loop's instruction.
    """

    name = "costmodel"

    def __init__(self, profile: HardwareProfile = TPU_V5E, chips: Optional[int] = None):
        self.profile = profile
        self.chips = chips

    def evaluate(self, fn: Callable, args: Sequence[Any] = (), reference=None) -> Measurement:
        try:
            compiled = fn(*args)
            terms = roofline_from_compiled(compiled, self.profile, self.chips)
        except Exception as e:
            return Measurement(math.inf, False, error=f"{type(e).__name__}: {e}")
        return Measurement(
            terms.step_time_s,
            True,
            meta={"roofline": terms.to_json()},
        )
