from .base import INVALID, SearchAlgorithm, SearchResult, Trial
from .exhaustive import ExhaustiveSearch
from .random_search import RandomSearch
from .coordinate import CoordinateDescent
from .anneal import SimulatedAnnealing
from .genetic import GeneticSearch

ALGORITHMS = {
    a.name: a
    for a in (
        ExhaustiveSearch,
        RandomSearch,
        CoordinateDescent,
        SimulatedAnnealing,
        GeneticSearch,
    )
}


def make_search(name: str, **kwargs) -> SearchAlgorithm:
    if name not in ALGORITHMS:
        raise KeyError(f"unknown search algorithm {name!r}; have {sorted(ALGORITHMS)}")
    return ALGORITHMS[name](**kwargs)
