"""Exhaustive search — ground truth for small spaces (Orio's `Exhaustive`)."""
from __future__ import annotations

from ..params import ParamSpace
from .base import SearchAlgorithm, SearchResult, ObjectiveFn, _Memo


class ExhaustiveSearch(SearchAlgorithm):
    name = "exhaustive"

    def run(self, space: ParamSpace, objective: ObjectiveFn) -> SearchResult:
        memo = _Memo(objective)
        for cfg in space.enumerate():
            if memo.evaluations >= self.budget:
                break
            memo(cfg)
        return self._mk_result(memo.trials)
