"""Exhaustive search — ground truth for small spaces (Orio's `Exhaustive`)."""
from __future__ import annotations

from typing import Sequence

from ..params import Config, ParamSpace
from .base import SearchAlgorithm, SearchResult, ObjectiveFn, _Memo


class ExhaustiveSearch(SearchAlgorithm):
    name = "exhaustive"

    def run(
        self,
        space: ParamSpace,
        objective: ObjectiveFn,
        seeds: Sequence[Config] = (),
    ) -> SearchResult:
        memo = _Memo(objective)
        # Seeds first: if the budget truncates the enumeration, the suggested
        # region still gets measured (memoization makes re-visits free).
        for cfg in self._valid_seeds(space, seeds):
            if memo.evaluations >= self.budget:
                break
            memo(cfg)
        for cfg in space.enumerate():
            if memo.evaluations >= self.budget:
                break
            memo(cfg)
        return self._mk_result(memo.trials)
