"""Coordinate descent / hillclimbing with random restarts.

This is the workhorse for tile spaces: performance is near-separable in the
block dims, so sweeping one knob at a time while holding others converges in
O(sum-of-domain-sizes) evaluations instead of O(product).
"""
from __future__ import annotations

from typing import Sequence

from ..params import Config, ParamSpace
from .base import INVALID, SearchAlgorithm, SearchResult, ObjectiveFn, _Memo, make_rng


class CoordinateDescent(SearchAlgorithm):
    name = "coordinate"

    def __init__(self, budget: int = 64, seed: int = 0, restarts: int = 3):
        super().__init__(budget, seed)
        self.restarts = restarts

    def run(
        self,
        space: ParamSpace,
        objective: ObjectiveFn,
        seeds: Sequence[Config] = (),
    ) -> SearchResult:
        rng = make_rng(self.seed)
        memo = _Memo(objective)

        def climb(start: Config) -> None:
            current = start
            cur_obj = memo(current).objective
            improved = True
            while improved and memo.evaluations < self.budget:
                improved = False
                for p in space.params:
                    # Sweep the whole domain of one knob, keep the best.
                    best_v, best_o = current[p.name], cur_obj
                    for v in p.choices:
                        if v == current[p.name]:
                            continue
                        cand = dict(current)
                        cand[p.name] = v
                        if not space.is_valid(cand):
                            continue
                        if memo.evaluations >= self.budget:
                            break
                        o = memo(cand).objective
                        if o < best_o:
                            best_v, best_o = v, o
                    if best_v != current[p.name]:
                        current = dict(current)
                        current[p.name] = best_v
                        cur_obj = best_o
                        improved = True

        # Warm start: climb from each transferred seed. A seed near the
        # optimum converges in one sweep, so the climb terminates well under
        # budget — that saved budget is the whole point of transfer tuning.
        warm = self._valid_seeds(space, seeds)
        for start in warm:
            if memo.evaluations >= self.budget:
                break
            climb(start)
        if not warm:
            for r in range(max(1, self.restarts)):
                if memo.evaluations >= self.budget:
                    break
                start = space.default() if r == 0 else space.sample(rng)
                climb(start)
        return self._mk_result(memo.trials)
