"""Genetic/evolutionary search (Orio's `Msimplex`/GA analogue).

Tournament selection + uniform crossover + one-knob mutation. Useful when
the space has interacting knobs (e.g. sharding layouts where dim assignments
must co-vary) where coordinate descent stalls on ridges.
"""
from __future__ import annotations

from typing import Sequence

from ..params import Config, ParamSpace
from .base import INVALID, SearchAlgorithm, SearchResult, ObjectiveFn, _Memo, make_rng


class GeneticSearch(SearchAlgorithm):
    name = "genetic"

    def __init__(
        self,
        budget: int = 64,
        seed: int = 0,
        population: int = 8,
        mutation_rate: float = 0.3,
        elite: int = 2,
    ):
        super().__init__(budget, seed)
        self.population = population
        self.mutation_rate = mutation_rate
        self.elite = elite

    def run(
        self,
        space: ParamSpace,
        objective: ObjectiveFn,
        seeds: Sequence[Config] = (),
    ) -> SearchResult:
        rng = make_rng(self.seed)
        memo = _Memo(objective)

        # Seeds join the founding population; the rest is random immigrants.
        pop = []
        for cfg in self._valid_seeds(space, seeds)[: self.population]:
            if memo.evaluations >= self.budget:
                break
            pop.append((memo(cfg).objective, cfg))
        while len(pop) < self.population:
            if memo.evaluations >= self.budget:
                break
            cfg = space.sample(rng)
            pop.append((memo(cfg).objective, cfg))

        def tournament():
            a, b = rng.choice(pop), rng.choice(pop)
            return a[1] if a[0] <= b[0] else b[1]

        proposals = 0
        # proposals cap: children may all be memo hits (evaluations stalls) —
        # bound total work explicitly.
        while memo.evaluations < self.budget and pop and proposals < self.budget * 20:
            pop.sort(key=lambda t: t[0])
            next_pop = pop[: self.elite]
            while (
                len(next_pop) < self.population
                and memo.evaluations < self.budget
                and proposals < self.budget * 20
            ):
                proposals += 1
                child = space.crossover(tournament(), tournament(), rng)
                if rng.random() < self.mutation_rate:
                    child = space.random_neighbor(child, rng)
                next_pop.append((memo(child).objective, child))
            pop = next_pop
        return self._mk_result(memo.trials)
