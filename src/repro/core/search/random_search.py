"""Uniform random search — the no-structure baseline (Orio's `Random`).

Surprisingly strong on tile spaces because good regions are wide; it is the
control every guided strategy must beat in ``benchmarks/search_convergence``.
"""
from __future__ import annotations

from ..params import ParamSpace
from .base import SearchAlgorithm, SearchResult, ObjectiveFn, _Memo, make_rng


class RandomSearch(SearchAlgorithm):
    name = "random"

    def run(self, space: ParamSpace, objective: ObjectiveFn) -> SearchResult:
        rng = make_rng(self.seed)
        memo = _Memo(objective)
        tries = 0
        # Allow a few duplicates' worth of extra draws, then stop.
        while memo.evaluations < self.budget and tries < self.budget * 4:
            tries += 1
            memo(space.sample(rng))
        return self._mk_result(memo.trials)
