"""Uniform random search — the no-structure baseline (Orio's `Random`).

Surprisingly strong on tile spaces because good regions are wide; it is the
control every guided strategy must beat in ``benchmarks/search_convergence``.
"""
from __future__ import annotations

from typing import Sequence

from ..params import Config, ParamSpace
from .base import SearchAlgorithm, SearchResult, ObjectiveFn, _Memo, make_rng


class RandomSearch(SearchAlgorithm):
    name = "random"

    def run(
        self,
        space: ParamSpace,
        objective: ObjectiveFn,
        seeds: Sequence[Config] = (),
    ) -> SearchResult:
        rng = make_rng(self.seed)
        memo = _Memo(objective)
        for cfg in self._valid_seeds(space, seeds):
            if memo.evaluations >= self.budget:
                break
            memo(cfg)
        tries = 0
        # Allow a few duplicates' worth of extra draws, then stop.
        while memo.evaluations < self.budget and tries < self.budget * 4:
            tries += 1
            memo(space.sample(rng))
        return self._mk_result(memo.trials)
