"""Search-strategy interface (Orio's `search` module analogue).

A strategy proposes configs; the tuner evaluates them (compile + run +
correctness gate) and reports the measured objective back. Strategies are
*budgeted* (max evaluations) because each evaluation costs a compile+run,
exactly as in the paper.

The objective convention throughout is **lower is better** (seconds, or the
dominant roofline term in seconds).
"""
from __future__ import annotations

import dataclasses
import math
import random
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from ..params import Config, ParamSpace

INVALID = math.inf  # objective assigned to failed/incorrect variants


@dataclasses.dataclass
class Trial:
    config: Config
    objective: float          # seconds; INVALID if variant failed
    ok: bool                  # compiled, ran and passed the correctness gate
    meta: Dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class SearchResult:
    best: Optional[Trial]
    trials: List[Trial]
    evaluations: int

    @property
    def best_config(self) -> Config:
        if self.best is None:
            raise RuntimeError("search found no valid variant")
        return self.best.config

    @property
    def best_objective(self) -> float:
        if self.best is None:
            return INVALID
        return self.best.objective


ObjectiveFn = Callable[[Config], Trial]


class SearchAlgorithm:
    """Base class: drive `objective` for at most `budget` evaluations.

    ``seeds`` are externally-suggested starting configs (transfer tuning:
    winners from a neighbouring shape bucket or a sibling platform). Every
    strategy evaluates the valid seeds first — a good seed costs one
    evaluation and lets local strategies converge in a single sweep instead
    of climbing from the space default.
    """

    name = "base"

    def __init__(self, budget: int = 64, seed: int = 0):
        self.budget = int(budget)
        self.seed = int(seed)

    def run(
        self,
        space: ParamSpace,
        objective: ObjectiveFn,
        seeds: Sequence[Config] = (),
    ) -> SearchResult:
        raise NotImplementedError

    # Shared bookkeeping ----------------------------------------------------
    @staticmethod
    def _mk_result(trials: List[Trial]) -> SearchResult:
        ok = [t for t in trials if t.ok and t.objective < INVALID]
        best = min(ok, key=lambda t: t.objective) if ok else None
        return SearchResult(best=best, trials=trials, evaluations=len(trials))

    @staticmethod
    def _valid_seeds(space: ParamSpace, seeds: Sequence[Config]) -> List[Config]:
        """Filter + dedup seed configs; invalid suggestions are just dropped."""
        out: List[Config] = []
        seen = set()
        for s in seeds:
            if not space.is_valid(s):
                continue
            k = ParamSpace.config_key(s)
            if k not in seen:
                seen.add(k)
                out.append(dict(s))
        return out


class _Memo:
    """Dedup wrapper so no strategy re-evaluates (re-compiles) a config."""

    def __init__(self, objective: ObjectiveFn):
        self._objective = objective
        self.cache: Dict[str, Trial] = {}
        self.trials: List[Trial] = []

    def __call__(self, config: Config) -> Trial:
        key = ParamSpace.config_key(config)
        if key in self.cache:
            return self.cache[key]
        t = self._objective(config)
        self.cache[key] = t
        self.trials.append(t)
        return t

    @property
    def evaluations(self) -> int:
        return len(self.trials)


def make_rng(seed: int) -> random.Random:
    return random.Random(seed)
