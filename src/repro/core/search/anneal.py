"""Simulated annealing over one-knob-step neighborhoods (Orio's `Annealing`)."""
from __future__ import annotations

import math
from typing import Sequence

from ..params import Config, ParamSpace
from .base import INVALID, SearchAlgorithm, SearchResult, ObjectiveFn, _Memo, make_rng


class SimulatedAnnealing(SearchAlgorithm):
    name = "anneal"

    def __init__(
        self,
        budget: int = 64,
        seed: int = 0,
        t0: float = 1.0,
        cooling: float = 0.92,
    ):
        super().__init__(budget, seed)
        self.t0 = t0
        self.cooling = cooling

    def run(
        self,
        space: ParamSpace,
        objective: ObjectiveFn,
        seeds: Sequence[Config] = (),
    ) -> SearchResult:
        rng = make_rng(self.seed)
        memo = _Memo(objective)

        # Start from the best-ranked seed; extra seeds are measured only while
        # budget remains (each evaluation is a compile+run — never overdraw).
        warm = self._valid_seeds(space, seeds)
        current = warm[0] if warm else space.sample(rng)
        cur = memo(current)
        for cfg in warm[1:]:
            if memo.evaluations >= self.budget:
                break
            memo(cfg)
        t = self.t0
        proposals = 0
        # proposals cap: neighborhoods are finite, so once every neighbor is
        # memoized `evaluations` stops growing — bound total work explicitly.
        while memo.evaluations < self.budget and proposals < self.budget * 20:
            proposals += 1
            cand_cfg = space.random_neighbor(current, rng)
            if not cand_cfg:
                break
            cand = memo(cand_cfg)
            # Accept: always if better; with Boltzmann probability if worse.
            # Relative delta keeps the temperature scale unit-free (objectives
            # span microseconds to seconds across kernels).
            if cand.objective < cur.objective:
                current, cur = cand_cfg, cand
            elif cur.objective < INVALID and cand.objective < INVALID:
                rel = (cand.objective - cur.objective) / max(cur.objective, 1e-12)
                if rng.random() < math.exp(-rel / max(t, 1e-6)):
                    current, cur = cand_cfg, cand
            t *= self.cooling
            if t < 1e-4:  # reheat: escape basins late in the budget
                t = self.t0 / 2
                current = space.sample(rng)
                cur = memo(current)
        return self._mk_result(memo.trials)
