"""Qwen2-0.5B — GQA with QKV bias [arXiv:2407.10671].

24L, d_model 896, 14 heads (GQA kv=2), d_ff 4864, vocab 151936.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-0.5b",
    family="dense",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=151_936,
    head_dim=64,
    ffn_kind="swiglu",
    qkv_bias=True,
    rope_theta=1_000_000.0,
    notes="14 heads / 64 head_dim: smallest arch; vocab dominates params.",
)
