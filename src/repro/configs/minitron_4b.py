"""Minitron-4B — width-pruned Nemotron-4 [arXiv:2407.14679; hf].

32L, d_model 3072, 24 heads (GQA kv=8), d_ff 9216, vocab 256000.
Nemotron family uses squared-ReLU MLPs (2-matrix) and untied embeddings.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="minitron-4b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=9216,
    vocab_size=256_000,
    head_dim=128,
    ffn_kind="relu2",
    rope_theta=10_000.0,
    notes="24 heads is not divisible by the 16-way model axis — exercises "
    "the sharding solver's pad-heads/batch-all fallback (a tuned choice).",
)
