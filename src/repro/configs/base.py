"""Architecture + shape configuration system.

Every assigned architecture is an :class:`ArchConfig` instance in its own
module (``repro/configs/<id>.py``); ``get_config(name)`` resolves them.
``SHAPES`` carries the four assigned input-shape cells; ``input_specs``
builds the ShapeDtypeStruct stand-ins the multi-pod dry-run lowers against
(no allocation, per the brief).

`reduced()` produces the family-preserving smoke-test config: same block
pattern / attention kinds / MoE topology, tiny dims.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    mixer: str = "attn"          # attn | mamba | mlstm | slstm
    window: int = 0              # 0 = full attention; >0 = sliding window
    ffn: str = "dense"           # dense | moe | moe+dense | none


@dataclasses.dataclass(frozen=True)
class Segment:
    pattern: Tuple[LayerSpec, ...]
    repeats: int

    @property
    def num_layers(self) -> int:
        return len(self.pattern) * self.repeats


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    ffn_kind: str = "swiglu"     # swiglu | geglu | gelu | relu2
    qkv_bias: bool = False
    # attention pattern
    window: int = 0                        # SWA window for swa layers
    local_global_ratio: int = 0            # k local layers per 1 global
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_every: int = 1                     # MoE FFN every k-th layer
    moe_residual_dense: bool = False       # arctic: dense FFN ∥ MoE
    capacity_factor: float = 1.25
    # SSM / hybrid
    attn_every: int = 0                    # jamba: attention every k-th layer
    ssm_pattern: Tuple[str, ...] = ()      # xlstm: ("mlstm", "slstm")
    mamba_expand: int = 2
    mamba_d_state: int = 16
    # frontend stubs
    frontend: Optional[str] = None         # audio_frames | vision_patches
    num_prefix: int = 0                    # paligemma: 256 patch embeddings
    # numerics
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    rope_theta: float = 10000.0
    sub_quadratic: bool = False            # may run long_500k
    notes: str = ""

    # ---------------------------------------------------------------- helpers
    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    def segments(self) -> Tuple[Segment, ...]:
        """Decompose num_layers into scan-able homogeneous segments."""
        L = self.num_layers

        def ffn_for(layer_idx: int) -> str:
            if self.num_experts == 0:
                return "dense" if self.d_ff > 0 else "none"
            if (layer_idx % self.moe_every) == (self.moe_every - 1):
                return "moe+dense" if self.moe_residual_dense else "moe"
            return "dense"

        if self.ssm_pattern:  # xlstm: alternating recurrent blocks, no FFN
            pat = tuple(LayerSpec(mixer=m, ffn="none") for m in self.ssm_pattern)
            assert L % len(pat) == 0
            return (Segment(pat, L // len(pat)),)

        if self.attn_every:  # jamba: 1 attn + (attn_every-1) mamba per block
            k = self.attn_every
            assert L % k == 0
            pat = tuple(
                LayerSpec(
                    mixer=("attn" if i == 0 else "mamba"),
                    ffn=ffn_for(i),
                )
                for i in range(k)
            )
            return (Segment(pat, L // k),)

        if self.local_global_ratio:  # gemma3: 5 local : 1 global
            r = self.local_global_ratio
            blk = r + 1
            full_blocks, extra = divmod(L, blk)
            pat = tuple(
                LayerSpec(mixer="attn", window=(self.window if i < r else 0),
                          ffn=ffn_for(i))
                for i in range(blk)
            )
            segs = [Segment(pat, full_blocks)]
            if extra:
                tail = tuple(
                    LayerSpec(mixer="attn", window=self.window, ffn=ffn_for(i))
                    for i in range(extra)
                )
                segs.append(Segment(tail, 1))
            return tuple(segs)

        # homogeneous dense / moe / swa archs
        spec = LayerSpec(mixer="attn", window=self.window, ffn=ffn_for(0))
        if self.num_experts and self.moe_every > 1:
            pat = tuple(LayerSpec(mixer="attn", window=self.window, ffn=ffn_for(i))
                        for i in range(self.moe_every))
            assert L % self.moe_every == 0
            return (Segment(pat, L // self.moe_every),)
        return (Segment((spec,), L),)

    def reduced(self) -> "ArchConfig":
        """Family-preserving tiny config for CPU smoke tests."""
        scale = {
            "d_model": 64,
            "d_ff": 128 if self.d_ff > 0 else 0,
            "num_heads": 4,
            "num_kv_heads": max(1, min(self.num_kv_heads, 2)),
            "head_dim": 16,
            "vocab_size": 256,
            "num_experts": min(self.num_experts, 4),
            "experts_per_token": min(self.experts_per_token, 2),
            "num_prefix": min(self.num_prefix, 4),
            "window": min(self.window, 8) if self.window else 0,
        }
        # keep the layer pattern but few repeats
        seg_len = 1
        if self.ssm_pattern:
            seg_len = len(self.ssm_pattern)
        elif self.attn_every:
            seg_len = self.attn_every
        elif self.local_global_ratio:
            seg_len = self.local_global_ratio + 1
        elif self.num_experts and self.moe_every > 1:
            seg_len = self.moe_every
        layers = seg_len * 2
        return dataclasses.replace(
            self, num_layers=layers, dtype="float32", **scale
        )


# ---------------------------------------------------------------------------
# Shapes (the four assigned cells)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                    # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
    # The dev-host smoke cell: what `launch.train --smoke` runs, and what
    # `campaign plan --train-shapes train_smoke` pre-tunes — one name keeps
    # the planner and the launcher on the same shapes.
    "train_smoke": ShapeSpec("train_smoke", 64, 8, "train"),
}


def cell_is_runnable(cfg: ArchConfig, shape: ShapeSpec) -> Tuple[bool, str]:
    """The brief's skip rule: long_500k only for sub-quadratic archs."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, (
            f"{cfg.name} is a quadratic-attention arch; long_500k requires "
            "sub-quadratic attention (skip noted per brief)"
        )
    return True, ""


def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for one (arch × shape) cell.

    train/prefill: the full token batch (plus stub frontend embeddings);
    decode: one new token per sequence (cache specs come from the model).
    """
    B, S = shape.global_batch, shape.seq_len
    f = jnp.dtype(cfg.dtype)
    i = jnp.int32
    sd = jax.ShapeDtypeStruct
    if shape.kind in ("train", "prefill"):
        if cfg.frontend == "audio_frames":
            return {
                "embeds": sd((B, S, cfg.d_model), f),
                "labels": sd((B, S), i),
            }
        if cfg.frontend == "vision_patches":
            P = cfg.num_prefix
            return {
                "embeds": sd((B, P, cfg.d_model), f),
                "tokens": sd((B, S - P), i),
                "labels": sd((B, S), i),
                "loss_mask": sd((B, S), jnp.float32),
            }
        return {"tokens": sd((B, S), i), "labels": sd((B, S), i)}
    # decode: one token against a seq_len-deep cache
    return {"tokens": sd((B, 1), i), "pos": sd((), i)}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

ARCH_NAMES = (
    "minitron_4b",
    "qwen2_5_3b",
    "qwen2_0_5b",
    "gemma3_27b",
    "xlstm_1_3b",
    "musicgen_large",
    "arctic_480b",
    "mixtral_8x7b",
    "paligemma_3b",
    "jamba_1_5_large",
)

_ALIASES = {
    "minitron-4b": "minitron_4b",
    "qwen2.5-3b": "qwen2_5_3b",
    "qwen2-0.5b": "qwen2_0_5b",
    "gemma3-27b": "gemma3_27b",
    "xlstm-1.3b": "xlstm_1_3b",
    "musicgen-large": "musicgen_large",
    "arctic-480b": "arctic_480b",
    "mixtral-8x7b": "mixtral_8x7b",
    "paligemma-3b": "paligemma_3b",
    "jamba-1.5-large-398b": "jamba_1_5_large",
}


def get_config(name: str) -> ArchConfig:
    mod_name = _ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    if mod_name not in ARCH_NAMES:
        raise KeyError(f"unknown arch {name!r}; have {list(ARCH_NAMES)}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs() -> Dict[str, ArchConfig]:
    return {n: get_config(n) for n in ARCH_NAMES}
