from .base import (
    ARCH_NAMES,
    SHAPES,
    ArchConfig,
    LayerSpec,
    Segment,
    ShapeSpec,
    all_configs,
    cell_is_runnable,
    get_config,
    input_specs,
)
