"""Mixtral-8x7B — 8-expert top-2 MoE with SWA [arXiv:2401.04088].

32L, d_model 4096, 32 heads (GQA kv=8), expert d_ff 14336, vocab 32000,
sliding-window attention (4096) per the assignment line.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14_336,
    vocab_size=32_000,
    head_dim=128,
    ffn_kind="swiglu",
    window=4096,
    num_experts=8,
    experts_per_token=2,
    notes="8 experts < 16-way model axis: expert dim cannot fill the axis — "
    "the layout solver shards expert-ff instead (divisibility-driven).",
)
