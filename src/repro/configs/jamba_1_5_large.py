"""Jamba-1.5-Large (398B) — Mamba+attention 7:1, MoE 16e top-2 [arXiv:2403.19887].

72L, d_model 8192, 64 heads (GQA kv=8), d_ff 24576, vocab 65536.
Block structure: 8-layer super-block = 1 attention + 7 mamba layers, MoE FFN
every 2nd layer (16 experts, top-2). 72 = 9 super-blocks. Mamba state is
O(1) in sequence => sub-quadratic: long_500k runs (attention layers keep a
full-length KV cache; 9 of 72 layers).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24_576,
    vocab_size=65_536,
    head_dim=128,
    ffn_kind="swiglu",
    num_experts=16,
    experts_per_token=2,
    moe_every=2,
    attn_every=8,
    mamba_expand=2,
    mamba_d_state=16,
    sub_quadratic=True,
)
