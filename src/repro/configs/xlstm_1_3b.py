"""xLSTM-1.3B — sLSTM + mLSTM blocks [arXiv:2405.04517].

48L, d_model 2048, 4 heads, no separate FFN (d_ff=0: the blocks carry their
own up/down projections — mLSTM pf=2, sLSTM post-MLP pf=4/3).
Alternating mLSTM/sLSTM 1:1 (the config line gives no ratio; recorded in
DESIGN.md). Constant-size recurrent state => sub-quadratic: long_500k runs.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-1.3b",
    family="ssm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    ssm_pattern=("mlstm", "slstm"),
    sub_quadratic=True,
)
