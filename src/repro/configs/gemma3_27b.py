"""Gemma3-27B — 5:1 local:global attention, 128k context [hf:google/gemma-3].

62L, d_model 5376, 32 heads (GQA kv=16), d_ff 21504, vocab 262144.
head_dim 128 (the real model's choice; 5376/32=168 would break MXU tiling).
Local layers use a 1024-token sliding window -> windowed KV caches.
62 = 10×(5 local + 1 global) + 2 trailing local layers.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-27b",
    family="dense",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    d_ff=21_504,
    vocab_size=262_144,
    head_dim=128,
    ffn_kind="geglu",
    window=1024,
    local_global_ratio=5,
    rope_theta=1_000_000.0,
    notes="long_500k skipped: global layers are full attention and the "
    "design context is 128k (per brief's skip rule).",
)
