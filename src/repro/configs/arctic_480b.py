"""Snowflake Arctic-480B — dense-MoE hybrid [hf:Snowflake/snowflake-arctic-base].

35L, d_model 7168, 56 heads (GQA kv=8), dense d_ff 4864 in *parallel
residual* with a 128-expert top-2 MoE (expert d_ff 4864).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=4864,
    vocab_size=32_000,
    head_dim=128,
    ffn_kind="swiglu",
    num_experts=128,
    experts_per_token=2,
    moe_residual_dense=True,
    notes="56 heads not divisible by 16; 128 experts shard 8-per-device on "
    "the model axis (expert parallelism).",
)
