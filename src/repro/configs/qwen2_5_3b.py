"""Qwen2.5-3B — GQA with QKV bias [hf:Qwen/Qwen2.5-3B].

36L, d_model 2048, 16 heads (GQA kv=2), d_ff 11008, vocab 151936.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2.5-3b",
    family="dense",
    num_layers=36,
    d_model=2048,
    num_heads=16,
    num_kv_heads=2,
    d_ff=11_008,
    vocab_size=151_936,
    head_dim=128,
    ffn_kind="swiglu",
    qkv_bias=True,
    rope_theta=1_000_000.0,
)
