"""MusicGen-large — decoder-only over EnCodec tokens [arXiv:2306.05284].

48L, d_model 2048, 32 heads (kv=32, i.e. MHA), d_ff 8192, vocab 2048.
Backbone only (per brief): the EnCodec frontend is a stub — input_specs
provides precomputed frame embeddings (4 codebooks summed); text-conditioning
cross-attention omitted. GELU 2-matrix FFN (standard transformer decoder).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    head_dim=64,
    ffn_kind="gelu",
    frontend="audio_frames",
)
