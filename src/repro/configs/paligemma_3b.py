"""PaliGemma-3B — SigLIP + Gemma-2B VLM [arXiv:2407.07726].

Backbone (per brief, frontend stubbed): 18L, d_model 2048, 8 heads
(MQA kv=1), d_ff 16384, vocab 257216, head_dim 256 (gemma-2b geometry).
input_specs provides 256 precomputed patch embeddings as a prefix.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b",
    family="vlm",
    num_layers=18,
    d_model=2048,
    num_heads=8,
    num_kv_heads=1,
    d_ff=16_384,
    vocab_size=257_216,
    head_dim=256,
    ffn_kind="geglu",
    frontend="vision_patches",
    num_prefix=256,
    notes="MQA (kv=1) and 8 heads: neither shards 16-way — attention runs "
    "batch-parallel over the full mesh (solver fallback).",
)
